"""Production-hardening acceptance for SpmvService (ISSUE 6).

Covers the four pillars plus the satellite invariants:
  * typed exception hierarchy (legacy builtin bases preserved);
  * memory-budgeted operator LRU — the resident-bytes gauge never
    exceeds the budget, eviction never loses a plan (zero-re-tune
    plan-store reload), singleton overruns serve transiently;
  * admission control + QoS — per-key/global/byte limits, reject vs
    shed-oldest vs degrade-to-k1, priority classes;
  * dynamic matrices — update_values swaps values with NO replan,
    update_structure replans in the background behind a staleness gate
    with an atomic swap;
  * observability — latency percentiles from the bounded reservoir,
    self-consistent counters (requests == results + sheds + errors at
    quiescence), zero busy-wakes when quiescent;
  * the N-producer concurrency stress: every Future resolves, no
    deadlock, counters balance.
"""
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.spmv import opcache
from repro.matrices import generators as G
from repro.serving.errors import (BadRequest, KeyBusy, QueueFull,
                                  RequestShed, ServiceClosed, ServiceError,
                                  UnregisteredKey)
from repro.serving.spmv_service import SpmvService, _Reservoir


def _mats():
    return {"a": G.banded(256, 4, seed=1),
            "b": G.banded(256, 4, seed=9),
            "c": G.power_law(256, alpha=1.8, seed=3)}


def _force_stop(svc):
    """Tear down a service whose dispatcher is parked in a huge batch
    window without paying the drain (the backpressure-test pattern)."""
    with svc._cv:
        for q in svc._queues.values():
            q.clear()
        svc._queued = 0
        svc._queued_bytes = 0
        svc._stop = True
        svc._cv.notify_all()
    svc._worker.join(timeout=10)


# -- satellite: typed exception hierarchy ----------------------------------
def test_typed_errors_keep_builtin_bases():
    assert issubclass(ServiceClosed, RuntimeError)
    assert issubclass(QueueFull, RuntimeError)
    assert issubclass(RequestShed, QueueFull)
    assert issubclass(KeyBusy, RuntimeError)
    assert issubclass(UnregisteredKey, KeyError)
    assert issubclass(BadRequest, ValueError)
    for c in (ServiceClosed, QueueFull, KeyBusy, UnregisteredKey,
              BadRequest):
        assert issubclass(c, ServiceError)


def test_submit_raises_typed_errors():
    svc = SpmvService(max_batch=2, window_ms=1.0, engine="csr", cache=False)
    svc.register("a", _mats()["a"])
    with pytest.raises(UnregisteredKey):
        svc.submit("nope", np.zeros(4))
    with pytest.raises(BadRequest):
        svc.submit("a", np.zeros(7))
    with pytest.raises(UnregisteredKey):
        svc.update_values("nope", np.zeros(4))
    with pytest.raises(BadRequest):
        svc.update_values("a", np.zeros(7))
    svc.close()
    with pytest.raises(ServiceClosed):
        svc.submit("a", np.zeros(256))
    with pytest.raises(ServiceClosed):
        svc.update_values("a", np.zeros(256))


def test_queue_full_carries_retry_after():
    svc = SpmvService(max_batch=8, window_ms=5000.0, engine="csr",
                      cache=False, max_queue=2)
    svc.register("a", _mats()["a"])
    x = np.zeros(256)
    for _ in range(2):
        svc.submit("a", x)
    with pytest.raises(QueueFull) as ei:
        svc.submit("a", x)
    assert ei.value.retry_after_ms > 0
    assert "backpressure" in str(ei.value)
    _force_stop(svc)


# -- pillar 1: memory-budgeted LRU -----------------------------------------
def test_lru_evicts_under_budget_and_reloads_without_retune(monkeypatch,
                                                            tmp_path):
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path))
    mats = _mats()
    # probe the per-operator footprint with an unbudgeted twin first
    with SpmvService(max_batch=4, window_ms=1.0, engine="csr",
                     use_kernel="interpret") as probe:
        probe.register("a", mats["a"])
        nb = opcache.operator_nbytes(probe.operator("a"))
    assert nb > 0
    budget = int(2.5 * nb)          # room for two residents, never three
    with SpmvService(max_batch=4, window_ms=1.0, engine="csr",
                     use_kernel="interpret",
                     memory_budget_bytes=budget) as svc:
        for k, m in mats.items():
            svc.register(k, m)
        for k in ("a", "b", "c"):
            svc.operator(k)
        s = svc.stats()
        assert s["evictions"] >= 1
        assert s["resident_ops"] <= 2
        assert s["resident_bytes"] <= budget
        assert s["resident_bytes_max"] <= budget, \
            "the gauge must NEVER exceed the budget, even transiently"
        # "a" was evicted (LRU-first); re-resolving it must reload from
        # the plan store — device arrays restored, ZERO re-tune
        before = s["op_builds"]
        op = svc.operator("a")
        s2 = svc.stats()
        assert s2["op_builds"] == before + 1
        assert s2["op_reloads"] >= 1
        assert op.build_info["cache_hit"] is True
        assert op.build_info.get("tune_ms", 0.0) == 0.0
        # and it still answers correctly
        x = np.random.default_rng(0).standard_normal(256)
        y = svc.submit("a", x).result(timeout=30)
        want = mats["a"].spmv(x)
        assert np.abs(y - want).max() / (np.abs(want).max() + 1e-9) < 1e-4


def test_singleton_over_budget_serves_transiently(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path))
    mats = _mats()
    with SpmvService(max_batch=4, window_ms=1.0, engine="csr",
                     use_kernel="interpret", memory_budget_bytes=1) as svc:
        svc.register("a", mats["a"])
        x = np.random.default_rng(1).standard_normal(256)
        y = svc.submit("a", x).result(timeout=30)
        want = mats["a"].spmv(x)
        assert np.abs(y - want).max() / (np.abs(want).max() + 1e-9) < 1e-4
        s = svc.stats()
    assert s["resident_bytes"] == 0          # never tracked as resident
    assert s["resident_bytes_max"] == 0
    assert s["budget_overruns"] >= 1


# -- pillar 2: admission control + QoS -------------------------------------
def test_shed_oldest_fails_oldest_with_request_shed():
    svc = SpmvService(max_batch=8, window_ms=5000.0, engine="csr",
                      cache=False, max_queue=2, overload="shed-oldest")
    svc.register("a", _mats()["a"])
    x = np.zeros(256)
    f0 = svc.submit("a", x)
    f1 = svc.submit("a", x)
    f2 = svc.submit("a", x)          # admitted: f0 (oldest) is shed
    assert f0.done()
    with pytest.raises(RequestShed) as ei:
        f0.result(timeout=0)
    assert ei.value.retry_after_ms > 0
    assert not f1.done() and not f2.done()
    s = svc.stats()
    assert s["sheds"] == 1 and s["rejected"] == 0
    assert s["queued"] == 2
    _force_stop(svc)


def test_per_key_overflow_sheds_own_oldest_only():
    # a full PER-KEY queue is relieved from that key's own queue (drop-
    # oldest); other keys' work is untouched — shedding them could never
    # free the slot
    svc = SpmvService(max_batch=8, window_ms=5000.0, engine="csr",
                      cache=False, max_queue=2, overload="shed-oldest")
    mats = _mats()
    svc.register("lo", mats["a"], priority=0)
    svc.register("hi", mats["b"], priority=1)
    x = np.zeros(256)
    lo0 = svc.submit("lo", x)
    hi0 = svc.submit("hi", x)
    hi1 = svc.submit("hi", x)
    hi2 = svc.submit("hi", x)        # hi full: hi0 (own oldest) is shed
    assert isinstance(hi0.exception(timeout=0), RequestShed)
    assert not (lo0.done() or hi1.done() or hi2.done())
    assert svc.stats()["sheds"] == 1
    _force_stop(svc)


def test_priority_classes_protect_high_under_global_limit():
    svc = SpmvService(max_batch=8, window_ms=5000.0, engine="csr",
                      cache=False, max_queue=8, max_queue_global=3,
                      overload="shed-oldest")
    mats = _mats()
    svc.register("lo", mats["a"], priority=0)
    svc.register("hi", mats["b"], priority=1)
    x = np.zeros(256)
    lo0 = svc.submit("lo", x)
    lo1 = svc.submit("lo", x)
    hi0 = svc.submit("hi", x)
    # global limit hit; admitting hi sheds the LOWEST class's oldest
    hi1 = svc.submit("hi", x)
    assert isinstance(lo0.exception(timeout=0), RequestShed)
    assert not (lo1.done() or hi0.done() or hi1.done())
    # a lo request cannot shed hi work: the only remaining lo victim is
    # shed, then every queued request outranks it -> typed reject once
    # the global queue refills with hi traffic
    hi2 = svc.submit("hi", x)        # sheds lo1 (global limit again)
    assert isinstance(lo1.exception(timeout=0), RequestShed)
    with pytest.raises(QueueFull):
        svc.submit("lo", x)          # only hi queued: outranked, reject
    s = svc.stats()
    assert s["sheds"] == 2 and s["rejected"] == 1
    assert not (hi0.done() or hi1.done() or hi2.done())
    _force_stop(svc)


def test_degrade_to_k1_drains_instead_of_waiting_windows():
    # above the watermark (max_queue // 2) the dispatcher must stop
    # waiting out the (enormous) batch window and drain immediately
    svc = SpmvService(max_batch=8, window_ms=60000.0, engine="csr",
                      cache=False, max_queue=4, overload="degrade-to-k1")
    svc.register("a", _mats()["a"])
    x = np.zeros(256)
    futs = [svc.submit("a", x) for _ in range(4)]
    t0 = time.monotonic()
    for f in futs:
        f.result(timeout=30)
    assert time.monotonic() - t0 < 30, "drain mode must not wait windows"
    svc.close()


def test_global_queue_and_byte_limits():
    mats = _mats()
    svc = SpmvService(max_batch=8, window_ms=5000.0, engine="csr",
                      cache=False, max_queue=8, max_queue_global=3)
    svc.register("a", mats["a"])
    svc.register("b", mats["b"])
    x = np.zeros(256)
    svc.submit("a", x)
    svc.submit("a", x)
    svc.submit("b", x)
    with pytest.raises(QueueFull, match="global"):
        svc.submit("b", x)
    _force_stop(svc)
    svc2 = SpmvService(max_batch=8, window_ms=5000.0, engine="csr",
                       cache=False, max_queue=8,
                       max_queue_bytes=3 * x.nbytes)
    svc2.register("a", mats["a"])
    for _ in range(3):
        svc2.submit("a", x)
    with pytest.raises(QueueFull, match="payload"):
        svc2.submit("a", x)
    _force_stop(svc2)


# -- pillar 3: dynamic matrices --------------------------------------------
def test_update_values_swaps_without_replan(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path))
    mat = _mats()["a"]
    with SpmvService(max_batch=4, window_ms=1.0, engine="csr",
                     use_kernel="interpret") as svc:
        svc.register("a", mat)
        x = np.random.default_rng(2).standard_normal(256)
        y0 = svc.submit("a", x).result(timeout=30)
        plan_before = svc._plans["a"][2]
        builds_before = svc.stats()["op_builds"]
        svc.update_values("a", mat.vals * 3.0)
        y1 = svc.submit("a", x).result(timeout=30)
        s = svc.stats()
        assert s["value_swaps"] == 1
        assert s["replans"] == 0
        # same Plan object, no fresh plan() call, no re-tune
        assert svc._plans["a"][2] is plan_before
        assert s["op_builds"] == builds_before
        assert svc._build_info["a"].get("value_swap") is True
    want = 3.0 * mat.spmv(x)
    assert np.abs(y1 - want).max() / (np.abs(want).max() + 1e-9) < 1e-4
    assert not np.allclose(y0, y1)


def test_update_structure_background_replan_and_staleness_gate():
    a = G.banded(256, 4, seed=1)
    b = G.power_law(256, alpha=1.8, seed=7)     # different structure
    x = np.random.default_rng(3).standard_normal(256)
    with SpmvService(max_batch=4, window_ms=1.0, engine="csr",
                     cache=False, use_kernel="interpret") as svc:
        svc.register("m", a)
        assert np.abs(svc.submit("m", x).result(timeout=30)
                      - a.spmv(x)).max() < 1e-3
        # slow the replan down so the staleness gate is observable
        orig = svc._build_operator

        def slow(*args, **kw):
            time.sleep(0.3)
            return orig(*args, **kw)

        svc._build_operator = slow
        fut = svc.update_structure("m", b, staleness_s=0.0)
        # staleness 0: the key gates immediately — this request must be
        # answered from the NEW matrix once the replan lands, never from
        # the stale operator
        y = svc.submit("m", x).result(timeout=30)
        gen = fut.result(timeout=30)
        assert gen == svc._gen["m"]
        want = b.spmv(x)
        assert np.abs(y - want).max() / (np.abs(want).max() + 1e-9) < 1e-4
        s = svc.stats()
        assert s["replans"] == 1 and s["replan_errors"] == 0
        with pytest.raises(BadRequest):
            svc.update_structure("m", G.banded(128, 4, seed=1))  # shape


def test_update_structure_serves_stale_until_swap():
    a = G.banded(256, 4, seed=1)
    b = G.power_law(256, alpha=1.8, seed=7)
    x = np.random.default_rng(4).standard_normal(256)
    with SpmvService(max_batch=4, window_ms=1.0, engine="csr",
                     cache=False, use_kernel="interpret") as svc:
        svc.register("m", a)
        svc.submit("m", x).result(timeout=30)
        orig = svc._build_operator
        started = threading.Event()

        def slow(*args, **kw):
            started.set()
            time.sleep(0.5)
            return orig(*args, **kw)

        svc._build_operator = slow
        fut = svc.update_structure("m", b)     # no staleness bound
        assert started.wait(timeout=10)
        # while the replan runs, the STALE operator keeps answering
        y_stale = svc.submit("m", x).result(timeout=30)
        want_a = a.spmv(x)
        assert np.abs(y_stale - want_a).max() \
            / (np.abs(want_a).max() + 1e-9) < 1e-4
        fut.result(timeout=30)
        y_new = svc.submit("m", x).result(timeout=30)
        want_b = b.spmv(x)
        assert np.abs(y_new - want_b).max() \
            / (np.abs(want_b).max() + 1e-9) < 1e-4


def test_update_values_refused_during_replan():
    a = G.banded(256, 4, seed=1)
    b = G.power_law(256, alpha=1.8, seed=7)
    with SpmvService(max_batch=4, window_ms=1.0, engine="csr",
                     cache=False, use_kernel="interpret") as svc:
        svc.register("m", a)
        svc.operator("m")
        orig = svc._build_operator
        svc._build_operator = lambda *a_, **k: (time.sleep(0.4),
                                                orig(*a_, **k))[1]
        fut = svc.update_structure("m", b)
        with pytest.raises(KeyBusy):
            svc.update_values("m", a.vals * 2.0)
        with pytest.raises(KeyBusy):
            svc.update_structure("m", b)
        fut.result(timeout=30)


# -- satellite: CV wakeups + observability ---------------------------------
def test_quiescent_service_never_busy_wakes():
    with SpmvService(max_batch=4, window_ms=2.0, engine="csr",
                     cache=False) as svc:
        svc.register("a", _mats()["a"])
        before = svc.stats()["wakeups"]
        time.sleep(0.5)
        assert svc.stats()["wakeups"] == before, \
            "idle dispatcher must sleep on the CV, not poll"
        # and after real work quiesces, it goes back to zero wakes
        x = np.zeros(256)
        for _ in range(5):
            svc.submit("a", x)
        svc.flush(timeout=30)
        settled = svc.stats()["wakeups"]
        time.sleep(0.4)
        assert svc.stats()["wakeups"] == settled


def test_latency_percentiles_from_reservoir():
    mat = _mats()["a"]
    with SpmvService(max_batch=4, window_ms=1.0, engine="csr",
                     cache=False, use_kernel="interpret") as svc:
        svc.register("a", mat)
        rng = np.random.default_rng(5)
        futs = [svc.submit("a", rng.standard_normal(256))
                for _ in range(20)]
        svc.flush(timeout=60)
        for f in futs:
            f.result(timeout=10)
        slo = svc.stats()["slo"]
    assert slo["latency_samples"] == 20
    assert 0 < slo["p50_ms"] <= slo["p95_ms"] <= slo["p99_ms"]
    assert slo["throughput_rps"] > 0


def test_reservoir_is_bounded_and_counts_all():
    r = _Reservoir(size=64, seed=0)
    for i in range(5000):
        r.add(float(i))
    assert r.count == 5000
    assert len(r.snapshot()) == 64


def test_stats_snapshot_counters_balance_after_close_drop():
    svc = SpmvService(max_batch=8, window_ms=60000.0, engine="csr",
                      cache=False)
    svc.register("a", _mats()["a"])
    fut = svc.submit("a", np.zeros(256))
    with pytest.raises(TimeoutError):
        svc.close(timeout=0.05)      # drain cannot finish: window is huge
    assert isinstance(fut.exception(timeout=5), ServiceClosed)
    s = svc.stats()
    assert s["requests"] == s["results"] + s["sheds"] + s["errors"] == 1
    assert s["pending"] == 0


# -- satellite: concurrency stress -----------------------------------------
@pytest.mark.parametrize("overload", ["reject", "shed-oldest"])
def test_producer_stress_every_future_resolves(monkeypatch, tmp_path,
                                               overload):
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path))
    mats = _mats()
    svc = SpmvService(max_batch=8, window_ms=1.0, engine="csr",
                      use_kernel="interpret", max_queue=16,
                      overload=overload,
                      memory_budget_bytes=1 << 20)
    svc.register("a", mats["a"])
    svc.register("b", mats["b"])
    futures = []
    flock = threading.Lock()
    n_threads, per_thread = 4, 30

    def produce(tid):
        rng = np.random.default_rng(tid)
        for i in range(per_thread):
            key = ("a", "b")[int(rng.integers(2))]
            try:
                f = svc.submit(key, rng.standard_normal(256))
                with flock:
                    futures.append(f)
            except QueueFull:
                pass                         # typed + retryable: fine
            if i % 10 == 5:
                try:
                    svc.update_values(key, mats[key].vals * (1 + 0.1 * i))
                except (KeyBusy, ServiceClosed):
                    pass

    threads = [threading.Thread(target=produce, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    svc.register("c", mats["c"])             # concurrent registration
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "producer deadlocked"
    svc.close(timeout=60)
    resolved = 0
    for f in futures:
        assert f.done(), "a Future was silently dropped"
        if f.exception(timeout=0) is None:
            resolved += 1
        else:
            assert isinstance(f.exception(timeout=0),
                              (ServiceError, RuntimeError))
    s = svc.stats()
    assert s["requests"] == s["results"] + s["sheds"] + s["errors"]
    assert s["pending"] == 0
    assert resolved == s["results"]
    # a second close must be a no-op, not a deadlock
    svc.close(timeout=5)
