"""Reordering schemes: validity, quality, and scheme-specific invariants."""
import numpy as np
import pytest
import scipy.sparse.csgraph as csg
from hypothesis import given, settings, strategies as st

from repro.core.reorder import api
from repro.core.reorder.metis import metis_partition
from repro.core.reorder.patoh import connectivity_cut, patoh_partition
from repro.core.sparse import metrics, partition
from repro.core.sparse.csr import CSRMatrix
from repro.matrices import generators as G

SCHEMES = list(api.SCHEMES)


@pytest.fixture(scope="module")
def corpus():
    return {
        "banded_shuf": G.shuffle(G.banded(512, 4, 0), 1),
        "stencil_shuf": G.shuffle(G.stencil_2d(24, seed=2), 3),
        "sbm": G.shuffle(G.sbm(768, 6, 0.06, 0.001, seed=4), 5),
        "rmat": G.rmat(9, 5, seed=6),
    }


@pytest.mark.parametrize("scheme", SCHEMES)
def test_permutation_valid(corpus, scheme):
    for mat in corpus.values():
        perm = api.reorder(mat, scheme, cache=False)
        assert perm.shape == (mat.m,)
        assert np.array_equal(np.sort(perm), np.arange(mat.m))


@pytest.mark.parametrize("scheme", SCHEMES)
def test_reorder_preserves_spectrum_sample(corpus, scheme):
    """Permutation similarity: A and PAP^T have identical eigenvalues."""
    mat = corpus["banded_shuf"]
    sub = CSRMatrix.from_dense(mat.to_dense()[:96, :96])
    perm = api.reorder(sub, scheme, cache=False)
    w0 = np.sort(np.linalg.eigvalsh(sub.to_dense()))
    w1 = np.sort(np.linalg.eigvalsh(sub.permute(perm).to_dense()))
    assert np.allclose(w0, w1, atol=1e-8)


def test_rcm_matches_scipy_bandwidth(corpus):
    """Our RCM must reach scipy's bandwidth (+/- small slack) on every matrix."""
    for name, mat in corpus.items():
        ours = metrics.bandwidth(mat.permute(api.reorder(mat, "rcm", cache=False)))
        sp = np.asarray(csg.reverse_cuthill_mckee(mat.to_scipy(), symmetric_mode=True),
                        dtype=np.int64)
        theirs = metrics.bandwidth(mat.permute(sp))
        assert ours <= max(theirs * 1.25, theirs + 8), (name, ours, theirs)


def test_rcm_recovers_banded_structure():
    mat = G.shuffle(G.banded(1024, 6, 0), 1)
    bw = metrics.bandwidth(mat.permute(api.reorder(mat, "rcm", cache=False)))
    assert bw <= 16  # original half-bandwidth 6 -> RCM near-optimal


def test_metis_cuts_communication(corpus):
    mat = corpus["sbm"]
    base_cut = metrics.cut_volume(mat, partition.static_partition(mat, 8))
    rm = mat.permute(api.reorder(mat, "metis", cache=False))
    metis_cut = metrics.cut_volume(rm, partition.static_partition(rm, 8))
    assert metis_cut < base_cut * 0.8


def test_louvain_finds_planted_communities():
    mat = G.shuffle(G.sbm(512, 4, 0.2, 0.001, seed=0), 1)
    rm = mat.permute(api.reorder(mat, "louvain", cache=False))
    base_cut = metrics.cut_volume(mat, partition.static_partition(mat, 4))
    lv_cut = metrics.cut_volume(rm, partition.static_partition(rm, 4))
    assert lv_cut < base_cut


def test_patoh_connectivity_objective(corpus):
    mat = corpus["sbm"]
    labels = patoh_partition(mat, 2, seed=0)
    side = (labels > 0).astype(np.int8)
    rng = np.random.default_rng(0)
    rand_cut = connectivity_cut(mat, rng.permutation(side))
    assert connectivity_cut(mat, side) < rand_cut


def test_metis_partition_balanced(corpus):
    mat = corpus["rmat"]
    labels = metis_partition(mat, 8, seed=0)
    counts = np.bincount(labels, minlength=8)
    assert counts.max() <= mat.m / 8 * 1.6


def test_cache_roundtrip(tmp_path, monkeypatch, corpus):
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path))
    mat = corpus["banded_shuf"]
    p1 = api.reorder(mat, "rcm", cache=True)
    p2 = api.reorder(mat, "rcm", cache=True)  # from cache
    assert np.array_equal(p1, p2)


@given(st.integers(16, 128), st.integers(0, 8))
@settings(max_examples=10, deadline=None)
def test_property_rcm_never_widens_optimal_band(m, seed):
    """RCM on an already-banded matrix should stay within ~2x of its band."""
    mat = G.banded(m, 2, seed=seed)
    bw = metrics.bandwidth(mat.permute(api.reorder(mat, "rcm", cache=False)))
    assert bw <= 8
