"""Tier-1 coverage for repro.workloads (ISSUE 9).

Promotes the sorted-vs-onehot agreement check out of the bench script,
pins the structure_key amortization invariants with obs.snapshot()
counter deltas, and runs the "workload" cell kind through the Runner
with full ResultStore resumability.
"""
import numpy as np
import pytest

from repro import obs
from repro import workloads as W
from repro.core.spmv.plan import structure_key, values_key
from repro.experiments import ExperimentSpec, MeasurePolicy, ResultStore, Runner
from repro.matrices import suite

MOE = "workload://moe-e8-k2-t128-d16-n3"
ATTN = "workload://attn-s128-b32-w2-g1-d8-n3"
GNN = "workload://gnn-m128-deg4-f8-n3"


def _delta(before, after, name):
    b = before["counters"].get(name, 0)
    return after["counters"].get(name, 0) - b


# --------------------------------------------------------------------------
# sorted-vs-onehot agreement (promoted from benchmarks/moe_dispatch)
# --------------------------------------------------------------------------
class TestSortedVsOnehot:
    def test_stream_agrees_with_onehot_oracle(self):
        rec = W.run_stream(W.DynamicSparseProblem(MOE, scenario="drift"),
                           iters=2)
        # combine output: summation orders differ -> tolerance; dispatch
        # buffer: pure placement (one nnz of 1.0 per slot row) -> bitwise
        assert rec["verify_ok"] and rec["max_rel_err"] < 1e-3
        assert rec["dispatch_bitwise_equal"]

    def test_moe_adapter_matches_onehot_reference(self):
        rng = np.random.default_rng(0)
        n, d, e, k = 96, 8, 4, 2
        x = rng.standard_normal((n, d)).astype(np.float32)
        wr = rng.standard_normal((d, e)).astype(np.float32)
        buf, y, info = W.moe_sorted_dispatch(x, wr, k, e)
        gates, experts = W.moe_route_np(x, wr, k)
        import jax.numpy as jnp

        from repro.workloads.adapters import _onehot_dispatch_combine

        ref_buf, ref_y = _onehot_dispatch_combine(
            jnp.asarray(x), jnp.asarray(experts), jnp.asarray(gates),
            num_experts=e, cap=info["cap"])
        assert np.array_equal(buf, np.asarray(ref_buf))
        err = np.abs(y - np.asarray(ref_y)).max()
        assert err < 1e-3 * max(np.abs(ref_y).max(), 1.0)

    def test_attn_and_gnn_adapters_match_dense_oracle(self):
        rng = np.random.default_rng(1)
        for name in (ATTN, GNN):
            step = next(W.DynamicSparseProblem(name).steps())
            mat, x = step.operands[0].mat, step.operands[0].x
            if name == ATTN:
                got = W.block_sparse_attention(mat, x,
                                               block=step.meta["block"])
            else:
                got = W.gnn_aggregate(mat, x)
            want = mat.to_dense() @ x
            assert np.abs(got - want).max() < 1e-4 * \
                (np.abs(want).max() + 1.0), name
        del rng


# --------------------------------------------------------------------------
# structure_key stability under the dynamic path (obs.snapshot pins)
# --------------------------------------------------------------------------
class TestAmortization:
    def test_value_only_stream_never_replans(self):
        before = obs.snapshot()
        prob = W.DynamicSparseProblem(MOE, scenario="static")
        rec = W.run_stream(prob, iters=1, compare_dense=False)
        after = obs.snapshot()
        assert rec["replans"] == 0
        assert _delta(before, after, "workload.replans") == 0
        # identical routing -> structure reuse every step after the first
        assert _delta(before, after, "workload.reuses") \
            + _delta(before, after, "workload.rebuilds") == rec["reuses"] \
            + rec["rebuilds"] > 0
        assert rec["reuse_rate"] > 0

    def test_one_structure_change_replans_exactly_once(self):
        before = obs.snapshot()
        rec = W.run_stream(W.DynamicSparseProblem(GNN, scenario="shift1"),
                           iters=1, compare_dense=False)
        after = obs.snapshot()
        assert rec["replans"] == 1
        assert _delta(before, after, "workload.replans") == 1
        assert _delta(before, after, "workload.plans") == 1

    def test_structure_and_values_keys_split_content(self):
        import dataclasses

        step = next(W.DynamicSparseProblem(GNN).steps())
        mat = step.operands[0].mat
        same_structure = dataclasses.replace(
            mat, vals=(mat.vals * 2.0).astype(np.float32))
        assert structure_key(mat) == structure_key(same_structure)
        assert values_key(mat) != values_key(same_structure)

    def test_session_events_reuse_vs_rebuild(self):
        prob = W.DynamicSparseProblem(GNN, scenario="static")
        sess = W.WorkloadSession(prob)
        steps = list(prob.steps())
        _, e0 = sess.operator(steps[0].operands[0].mat, role="aggregate")
        _, e1 = sess.operator(steps[1].operands[0].mat, role="aggregate")
        # static gnn changes edge weights per step: same structure, new
        # values -> rebuild (not replan, not plain reuse)
        assert (e0, e1) == ("plans", "rebuilds")
        same = sess.operator(steps[1].operands[0].mat, role="aggregate")[1]
        assert same == "reuses"


# --------------------------------------------------------------------------
# names, suite integration, cell kind + resumability
# --------------------------------------------------------------------------
class TestSuiteAndCells:
    def test_name_grammar(self):
        wd = W.parse_workload("workload://moe-e16-k4-t512")
        assert wd.params["e"] == 16 and wd.params["k"] == 4
        assert wd.params["d"] == 32          # default survives
        with pytest.raises(ValueError):
            W.parse_workload("workload://nope-e2")
        with pytest.raises(ValueError):
            W.parse_workload("workload://moe-z9")
        with pytest.raises(ValueError):
            W.parse_workload("moe-e2")

    def test_suite_resolves_representative(self):
        mat = suite.get(MOE)
        assert mat.shape[1] == 128           # dispatch: [E*cap, tokens]
        assert set(suite.workload_names()) == set(W.preset_names())
        assert "workload" in suite.TIERS

    def test_moe_cell_rejects_reordering_schemes(self, tmp_path):
        from repro.experiments.cells import measure_workload_cell
        from repro.experiments.spec import Cell

        pol = MeasurePolicy(iters=1, warmup=0)
        cell = Cell(kind="workload", matrix=MOE, scheme="rcm",
                    engine="auto", dtype="float32", p=1, k=1, variant="",
                    policy=tuple(sorted(pol.resolve("*").items())))
        with pytest.raises(ValueError, match="rectangular"):
            measure_workload_cell(cell, None)

    def test_workload_cells_resume_from_store(self, tmp_path):
        spec = ExperimentSpec(
            name="t_workloads", matrices=(GNN,), schemes=("baseline",),
            engines=("auto",), kind="workload",
            variants=("static", "shift1"),
            policy=MeasurePolicy(iters=1, warmup=0, verify=True))
        store = ResultStore(str(tmp_path))
        rep = Runner(spec, store=store, verbose=False).run()
        assert rep.measured == 2 and not rep.failures
        by_scen = {r["variant"]: r for r in rep.records}
        assert by_scen["static"]["replans"] == 0
        assert by_scen["shift1"]["replans"] == 1
        for r in rep.records:
            assert r["verify_ok"]
            assert 0.0 <= r["plan_cost_share"] <= 1.0
            assert r["steps"] == 3 and len(r["per_step"]) == 3
        rep2 = Runner(spec, store=store, verbose=False).run()
        assert rep2.measured == 0 and rep2.reused == 2
