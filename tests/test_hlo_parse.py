"""Unit tests for the HLO collective parsers (both replica_groups forms)."""
from repro.launch import hlo, hlo_cost

LINE_BRACKET = ('  %all-gather = f32[64,64]{0,1} all-gather(%bitcast), '
                'channel_id=1, replica_groups=[4,16]<=[64], dimensions={1}')
LINE_SET = ('  %all_gather.5 = f32[1024]{0} all-gather(%gte), channel_id=1, '
            'replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}')
LINE_AR = ('  %all-reduce.1 = f32[2048]{0} all-reduce(%p), '
           'replica_groups=[1,8]<=[8], to_apply=%add')


def test_group_size_bracket_form():
    assert hlo_cost._group_size(LINE_BRACKET) == 16


def test_group_size_set_form():
    assert hlo_cost._group_size(LINE_SET) == 4


def test_collective_bytes_wire_model():
    mod = "\n".join([
        "HloModule m",
        "ENTRY %main (p: f32[2048]) -> f32[2048] {",
        LINE_AR,
        "}",
    ])
    rec = hlo.collective_bytes(mod)
    assert rec["all-reduce"] == 2048 * 4
    # ring all-reduce wire = 2*S*(g-1)/g
    assert abs(rec["wire"] - 2 * 2048 * 4 * 7 / 8) < 1

def test_walker_counts_set_form_groups():
    mod = "\n".join([
        "HloModule m",
        "ENTRY %main (p: f32[1024]) -> f32[1024] {",
        LINE_SET,
        "}",
    ])
    rec = hlo_cost.analyze_text(mod)
    ag = rec["collectives"]["all-gather"]
    assert ag == 1024 * 4 / 4  # operand = result / group_size
    assert rec["collectives"]["wire"] > 0
