"""Streaming MatrixMarket ingestion + .csrz artifact cache (repro.corpus).

Covers the corpus I/O contract end to end: header validation (the
rejects the seed reader silently mis-parsed), symmetric/pattern/integer
semantics, chunked-vs-whole-file equivalence against an in-test oracle
written in the seed's np.loadtxt style, the >=100k-row chunk-count
accounting that pins peak parser memory, bit-identical .csrz round
trips, corruption tolerance, and the parse-once-ever cache hit.
"""
import json
import math
import os

import numpy as np
import pytest

from repro import obs
from repro.core.sparse.csr import CSRMatrix
from repro.corpus import artifact, mtxstream
from repro.matrices import generators
from repro.matrices.io import read_mtx, write_mtx


@pytest.fixture()
def corpus_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CORPUS_CACHE", str(tmp_path / "corpus"))
    return tmp_path


def _write(tmp_path, text, name="t.mtx"):
    p = tmp_path / name
    p.write_text(text)
    return str(p)


def _dense(mat: CSRMatrix) -> np.ndarray:
    out = np.zeros(mat.shape, dtype=np.float64)
    for i in range(mat.m):
        lo, hi = mat.rowptr[i], mat.rowptr[i + 1]
        np.add.at(out[i], mat.cols[lo:hi], mat.vals[lo:hi])
    return out


def _oracle_read(path: str) -> CSRMatrix:
    """The seed's whole-file reader, kept as a test oracle: slurp every
    data line through np.loadtxt and assemble via from_coo."""
    with open(path) as f:
        banner = f.readline().split()
        field, sym = banner[3].lower(), banner[4].lower()
        line = f.readline()
        while line.startswith("%") or not line.strip():
            line = f.readline()
        m, n, nnz = (int(t) for t in line.split())
        data = np.loadtxt(f, dtype=np.float64,
                          ndmin=2) if nnz else np.zeros((0, 3))
    r = data[:, 0].astype(np.int64) - 1
    c = data[:, 1].astype(np.int64) - 1
    v = (np.ones(r.size) if field == "pattern"
         else data[:, 2].astype(np.float64))
    if sym == "symmetric":
        off = r != c
        r, c, v = (np.concatenate([r, c[off]]), np.concatenate([c, r[off]]),
                   np.concatenate([v, v[off]]))
    return CSRMatrix.from_coo(r, c, v, (m, n))


# -------------------------------------------------------------------------
# header validation
# -------------------------------------------------------------------------
@pytest.mark.parametrize("banner,match", [
    ("%%MatrixMarket matrix coordinate complex general", "complex"),
    ("%%MatrixMarket matrix coordinate real hermitian", "hermitian"),
    ("%%MatrixMarket matrix coordinate real skew-symmetric",
     "skew-symmetric"),
    ("%%MatrixMarket matrix array real general", "array|coordinate"),
    ("%%MatrixMarket vector coordinate real general", "vector"),
    ("%%MatrixMarket matrix coordinate quaternion general", "quaternion"),
    ("%%MatrixMarket matrix coordinate real upper-magic", "upper-magic"),
])
def test_reject_unsupported_headers(tmp_path, banner, match):
    path = _write(tmp_path, banner + "\n2 2 1\n1 1 1.0\n")
    with pytest.raises(ValueError, match=match):
        mtxstream.read_header(path)


def test_reject_non_mtx_and_malformed(tmp_path):
    with pytest.raises(ValueError, match="not a MatrixMarket"):
        mtxstream.read_header(_write(tmp_path, "hello world\n1 1 1\n"))
    with pytest.raises(ValueError, match="malformed MatrixMarket banner"):
        mtxstream.read_header(
            _write(tmp_path, "%%MatrixMarket matrix coordinate\n"))
    hdr = "%%MatrixMarket matrix coordinate real general\n"
    with pytest.raises(ValueError, match="size line"):
        mtxstream.read_header(_write(tmp_path, hdr + "2 2\n"))
    with pytest.raises(ValueError, match="three integers"):
        mtxstream.read_header(_write(tmp_path, hdr + "2 2 x\n"))
    with pytest.raises(ValueError, match="negative"):
        mtxstream.read_header(_write(tmp_path, hdr + "-2 2 1\n"))
    with pytest.raises(ValueError, match="square"):
        mtxstream.read_header(_write(
            tmp_path, "%%MatrixMarket matrix coordinate real symmetric\n"
            "2 3 1\n"))


def test_header_skips_comments_and_blank_lines(tmp_path):
    path = _write(tmp_path,
                  "%%MatrixMarket matrix coordinate real general\n"
                  "% a comment\n%another\n\n3 4 2\n1 1 5\n3 4 7\n")
    hdr = mtxstream.read_header(path)
    assert (hdr.m, hdr.n, hdr.nnz) == (3, 4, 2)
    assert hdr.field == "real" and not hdr.symmetric
    mat = read_mtx(path)
    assert mat.shape == (3, 4) and mat.nnz == 2
    assert _dense(mat)[0, 0] == 5 and _dense(mat)[2, 3] == 7


# -------------------------------------------------------------------------
# data-section validation
# -------------------------------------------------------------------------
def _general(m, n, entries):
    body = "".join(f"{r} {c} {v}\n" for r, c, v in entries)
    return ("%%MatrixMarket matrix coordinate real general\n"
            f"{m} {n} {len(entries)}\n" + body)


def test_truncated_file_rejected(tmp_path):
    path = _write(tmp_path,
                  "%%MatrixMarket matrix coordinate real general\n"
                  "2 2 3\n1 1 1.0\n2 2 2.0\n")
    with pytest.raises(ValueError, match="truncated"):
        read_mtx(path)


def test_trailing_data_rejected(tmp_path):
    path = _write(tmp_path, _general(2, 2, [(1, 1, 1.0)]) + "2 2 9.0\n")
    with pytest.raises(ValueError, match="beyond the declared"):
        read_mtx(path)


def test_out_of_range_and_garbage_rejected(tmp_path):
    for bad in [(0, 1, 1.0), (3, 1, 1.0), (1, 0, 1.0), (1, 5, 1.0)]:
        with pytest.raises(ValueError, match="out of range"):
            read_mtx(_write(tmp_path, _general(2, 2, [bad])))
    with pytest.raises(ValueError, match="non-numeric"):
        read_mtx(_write(tmp_path, _general(2, 2, [(1, "x", 1.0)])))
    with pytest.raises(ValueError, match="non-integer"):
        read_mtx(_write(tmp_path, _general(2, 2, [(1.5, 1, 1.0)])))
    with pytest.raises(ValueError, match="columns per entry"):
        read_mtx(_write(tmp_path,
                        "%%MatrixMarket matrix coordinate real general\n"
                        "2 2 2\n1 1 1.0\n2 2\n"))


def test_duplicates_merged_scipy_semantics(tmp_path):
    path = _write(tmp_path, _general(
        2, 2, [(1, 1, 1.0), (1, 1, 2.5), (2, 1, 4.0)]))
    mat, stats = mtxstream.parse_mtx(path)
    assert stats["duplicates_merged"] == 1
    assert mat.nnz == 2
    assert _dense(mat)[0, 0] == pytest.approx(3.5)
    assert _dense(mat)[1, 0] == pytest.approx(4.0)


# -------------------------------------------------------------------------
# field / symmetry semantics
# -------------------------------------------------------------------------
def test_pattern_field_yields_unit_values(tmp_path):
    path = _write(tmp_path,
                  "%%MatrixMarket matrix coordinate pattern symmetric\n"
                  "3 3 3\n1 1\n2 1\n3 2\n")
    mat = read_mtx(path)
    # two off-diagonal stored entries mirror; the diagonal does not
    assert mat.nnz == 5
    assert np.all(mat.vals == 1.0)
    d = _dense(mat)
    assert np.array_equal(d, d.T)


def test_integer_field_and_symmetric_mirror(tmp_path):
    path = _write(tmp_path,
                  "%%MatrixMarket matrix coordinate integer symmetric\n"
                  "3 3 4\n1 1 2\n2 1 -3\n3 1 5\n3 3 7\n")
    mat = read_mtx(path)
    assert mat.nnz == 6
    d = _dense(mat)
    assert np.array_equal(d, d.T)
    assert d[0, 1] == -3 and d[1, 0] == -3 and d[0, 0] == 2


def test_empty_matrix(tmp_path):
    mat = read_mtx(_write(tmp_path, _general(4, 3, [])))
    assert mat.shape == (4, 3) and mat.nnz == 0
    assert mat.rowptr.tolist() == [0] * 5


# -------------------------------------------------------------------------
# chunked-vs-oracle equivalence + round trips
# -------------------------------------------------------------------------
@pytest.mark.parametrize("gen", [
    lambda: generators.banded(60, 4, seed=3),
    lambda: generators.power_law(80, alpha=2.0, seed=5),
    lambda: generators.random_uniform(50, 6, seed=9),
])
def test_chunked_matches_oracle_and_roundtrip(tmp_path, gen):
    ref = gen()
    path = str(tmp_path / "m.mtx")
    write_mtx(path, ref)
    oracle = _oracle_read(path)
    for chunk in (7, 64, None):  # tiny chunks force many boundaries
        got = read_mtx(path, chunk_nnz=chunk)
        assert got.shape == oracle.shape == ref.shape
        assert np.array_equal(got.rowptr, oracle.rowptr.astype(got.rowptr.dtype))
        assert np.array_equal(got.cols, oracle.cols)
        np.testing.assert_array_equal(got.vals, oracle.vals)
        np.testing.assert_array_equal(got.vals, ref.vals.astype(np.float64))


def test_write_mtx_value_exact_roundtrip(tmp_path):
    rng = np.random.default_rng(42)
    m = generators.banded(40, 3, seed=1)
    vals = rng.standard_normal(m.nnz)  # full-precision doubles
    mat = CSRMatrix(rowptr=m.rowptr, cols=m.cols, vals=vals, shape=m.shape)
    path = str(tmp_path / "rt.mtx")
    write_mtx(path, mat)
    got = read_mtx(path)
    np.testing.assert_array_equal(got.vals, vals)  # %.17g is lossless


def test_scale_ingest_chunk_accounting(tmp_path):
    """>=100k-row ingest with bounded chunks: the chunk count must match
    2 * ceil(stored/chunk) (two streaming passes) and no chunk may exceed
    the requested size — the accounting that pins peak parser memory."""
    m = 110_000
    ref = generators.banded(m, 1, seed=7)  # tridiagonal: nnz = 3m - 2
    path = str(tmp_path / "big.mtx")
    write_mtx(path, ref)
    chunk = 65_536
    mat, stats = mtxstream.parse_mtx(path, chunk_nnz=chunk)
    assert mat.m == m >= 100_000
    assert mat.nnz == ref.nnz == 3 * m - 2
    assert stats["passes"] == 2
    assert stats["chunks"] == 2 * math.ceil(ref.nnz / chunk)
    assert 0 < stats["max_chunk_elems"] <= chunk
    np.testing.assert_array_equal(mat.vals, ref.vals.astype(np.float64))
    assert np.array_equal(mat.cols, ref.cols)


def test_chunk_nnz_validation(tmp_path):
    path = _write(tmp_path, _general(2, 2, [(1, 1, 1.0)]))
    with pytest.raises(ValueError, match="chunk_nnz"):
        mtxstream.parse_mtx(path, chunk_nnz=0)


# -------------------------------------------------------------------------
# .csrz artifacts
# -------------------------------------------------------------------------
def test_csrz_bit_identical_roundtrip(tmp_path):
    mat = generators.power_law(64, alpha=1.8, seed=4)
    zpath = artifact.save_csrz(str(tmp_path / "a.csrz"), mat)
    assert os.path.exists(zpath) and os.path.exists(zpath + ".json")
    loaded = artifact.load_csrz(zpath)
    assert loaded is not None
    got, meta = loaded
    assert got.shape == mat.shape
    np.testing.assert_array_equal(got.rowptr, mat.rowptr)
    np.testing.assert_array_equal(got.cols, mat.cols)
    np.testing.assert_array_equal(got.vals, mat.vals)
    assert meta["m"] == 64 and meta["nnz"] == mat.nnz
    assert "features" in meta and "locality" in meta


@pytest.mark.parametrize("corrupt", ["npz", "json", "schema", "missing"])
def test_csrz_corruption_tolerant(tmp_path, corrupt):
    mat = generators.banded(16, 2, seed=2)
    zpath = artifact.save_csrz(str(tmp_path / "c.csrz"), mat)
    jpath = zpath + ".json"
    if corrupt == "npz":
        with open(zpath, "wb") as f:
            f.write(b"not a zipfile")
    elif corrupt == "json":
        with open(jpath, "w") as f:
            f.write("{broken")
    elif corrupt == "schema":
        with open(jpath, "w") as f:
            json.dump({"schema": 999, "meta": {}}, f)
    else:
        os.remove(zpath)
    assert artifact.load_csrz(zpath) is None  # tolerant: caller re-parses


def test_ingest_parse_once_ever(tmp_path, corpus_cache):
    ref = generators.banded(32, 2, seed=6)
    path = str(tmp_path / "src.mtx")
    write_mtx(path, ref)

    def parses():
        return obs.snapshot()["counters"].get("corpus.parses", 0)

    p0 = parses()
    cold = artifact.ingest_path(path)
    assert not cold.cache_hit and cold.parse_stats is not None
    assert parses() == p0 + 1
    warm = artifact.ingest_path(path)
    assert warm.cache_hit and warm.parse_stats is None
    assert warm.key == cold.key == artifact.file_sha256(path)
    assert parses() == p0 + 1  # zero parse work on the hit
    np.testing.assert_array_equal(warm.mat.vals, cold.mat.vals)
    # same bytes at another path -> same content key -> still a hit
    path2 = str(tmp_path / "copy.mtx")
    with open(path) as f:
        data = f.read()
    with open(path2, "w") as f:
        f.write(data)
    assert artifact.ingest_path(path2).cache_hit
    assert parses() == p0 + 1


def test_ingest_cache_disabled(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CORPUS_CACHE", "off")
    ref = generators.banded(8, 1, seed=1)
    path = str(tmp_path / "nc.mtx")
    write_mtx(path, ref)
    res = artifact.ingest_path(path)
    assert not res.cache_hit and res.artifact == ""
    assert not artifact.cache_enabled()
