"""HLO cost walker vs closed-form counts (scan trip-count correctness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _analyze(f, *sds):
    compiled = jax.jit(f).lower(*sds).compile()
    return hlo_cost.analyze_text(compiled.as_text())


def test_single_matmul():
    n = 128
    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)
    rec = _analyze(lambda a, b: a @ b, sds, sds)
    want = 2 * n ** 3
    assert abs(rec["flops"] - want) / want < 0.05


def test_scan_multiplies_trip_count():
    n, trips = 64, 12
    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    rec = _analyze(f, sds, sds)
    want = trips * 2 * n ** 3
    assert abs(rec["flops"] - want) / want < 0.05, rec["flops"]


def test_nested_scan():
    n, outer, inner = 32, 5, 7
    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def f(x, w):
        def obody(c, _):
            def ibody(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(ibody, c, None, length=inner)
            return c, None
        y, _ = jax.lax.scan(obody, x, None, length=outer)
        return y

    rec = _analyze(f, sds, sds)
    want = outer * inner * 2 * n ** 3
    assert abs(rec["flops"] - want) / want < 0.05, rec["flops"]


def test_einsum_contraction():
    b, m, k, n = 4, 32, 48, 56
    a = jax.ShapeDtypeStruct((b, m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)
    rec = _analyze(lambda a, w: jnp.einsum("bmk,kn->bmn", a, w), a, w)
    want = 2 * b * m * k * n
    assert abs(rec["flops"] - want) / want < 0.05


def test_bytes_nonzero_and_scaled_by_scan():
    n, trips = 64, 9
    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    rec = _analyze(f, sds, sds)
    # at least trips * (read w + read c + write y)
    assert rec["bytes"] >= trips * 3 * n * n * 4


def test_collective_in_sharded_program():
    import subprocess, sys, textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch import hlo_cost
        mesh = jax.make_mesh((4,), ("d",))
        sh = NamedSharding(mesh, P(None, "d"))
        f = jax.jit(lambda x: (x @ x.T).sum(), in_shardings=sh)
        txt = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile().as_text()
        rec = hlo_cost.analyze_text(txt)
        assert rec["collectives"].get("total", 0) > 0, rec
        print("COLL_OK", rec["collectives"]["total"])
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert "COLL_OK" in r.stdout, r.stdout + r.stderr
