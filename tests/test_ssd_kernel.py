"""SSD chunk Pallas kernel vs oracle vs the model's scan implementation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk.kernel import ssd_chunk
from repro.kernels.ssd_chunk.ops import ssd_scan
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref


def _inputs(b, t, h, n, p, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    la = -jnp.asarray(rng.uniform(0.001, 0.2, (b, t, h)), jnp.float32)
    xw = jnp.asarray(rng.standard_normal((b, t, h, p)), dtype)
    bm = jnp.asarray(rng.standard_normal((b, t, n)), dtype)
    cm = jnp.asarray(rng.standard_normal((b, t, n)), dtype)
    st = jnp.asarray(rng.standard_normal((b, h, n, p)), dtype)
    return la, xw, bm, cm, st


@pytest.mark.parametrize("b,t,h,n,p", [(2, 16, 3, 8, 8), (1, 32, 2, 16, 8),
                                       (2, 8, 4, 4, 16)])
def test_kernel_matches_ref(b, t, h, n, p):
    args = _inputs(b, t, h, n, p)
    y_k, s_k = ssd_chunk(*args, interpret=True)
    y_r, s_r = ssd_chunk_ref(*args)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


def test_scan_matches_model_ssd():
    """ssd_scan(chunked kernel path) == the model's _ssd_chunked."""
    from repro.models.layers.mamba2 import _ssd_chunked

    b, s, h, n, p, chunk = 2, 64, 2, 8, 8, 16
    rng = np.random.default_rng(1)
    a_log = jnp.asarray(rng.uniform(-1, 1, (h,)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
    xh = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)

    y_model, s_model = _ssd_chunked(xh, dt, a_log, bm, cm, chunk)

    la = -jnp.exp(a_log) * dt
    xw = xh * dt[..., None]
    st0 = jnp.zeros((b, h, n, p), jnp.float32)
    y_ops, s_ops = ssd_scan(la, xw, bm, cm, st0, chunk=chunk,
                            use_kernel="interpret")
    np.testing.assert_allclose(np.asarray(y_ops), np.asarray(y_model),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_ops), np.asarray(s_model),
                               rtol=2e-3, atol=2e-3)


def test_bf16_tolerance():
    args = _inputs(1, 16, 2, 8, 8, seed=2, dtype=jnp.bfloat16)
    y_k, _ = ssd_chunk(*args, interpret=True)
    y_r, _ = ssd_chunk_ref(*args)
    scale = np.abs(np.asarray(y_r, np.float32)).max() + 1e-9
    assert np.abs(np.asarray(y_k, np.float32)
                  - np.asarray(y_r, np.float32)).max() / scale < 0.1
