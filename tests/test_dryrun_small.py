"""Launcher stack on a small fake mesh: lower+compile a train and a decode
cell end-to-end (subprocess: device count must precede jax init), plus the
HLO walkers on the results."""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs import registry
    from repro.configs.base import smoke_config, ShapeConfig
    from repro.distributed import sharding as SH
    from repro.launch import hlo_cost, specs as SPECS
    from repro.models import model as MDL
    from repro.serving.decode import make_serve_step
    from repro.training import optimizer as OPT, train_loop as TL

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = smoke_config(registry.get("qwen2-7b"))

    # ---- train cell ----
    with mesh:
        step, sh_fn, _ = TL.make_train_step(cfg, OPT.OptConfig(), mesh,
                                            ("data",), microbatches=2)
        state_shape = TL.init_state_shape(cfg)
        st_sh = sh_fn(state_shape["params"])
        batch = {"tokens": jax.ShapeDtypeStruct(
            (8, 64), jnp.int32,
            sharding=NamedSharding(mesh, P("data", None)))}
        lowered = jax.jit(step, in_shardings=(st_sh, None),
                          out_shardings=(st_sh, None)).lower(state_shape, batch)
        compiled = lowered.compile()
        walk = hlo_cost.analyze_text(compiled.as_text())
        assert walk["flops"] > 0
        assert walk["collectives"].get("total", 0) > 0  # TP must communicate
        print("TRAIN_OK", f"{walk['flops']:.3e}")

    # ---- decode cell ----
    shape = ShapeConfig("d", 128, 8, "decode")
    with mesh:
        pshape, psh = (lambda: (None, None))()
        pshape = jax.eval_shape(lambda k: MDL.init_params(cfg, k, jnp.bfloat16),
                                jax.random.PRNGKey(0))
        sp = SH.validate_specs(pshape, SH.param_specs(pshape), mesh)
        psh = SH.named_shardings(sp, mesh)
        serve = make_serve_step(cfg, mesh=mesh, dp_axes=("data",))
        cache_shape = SPECS.cache_shape(cfg, shape)
        csp = SPECS.cache_specs(cache_shape, cfg, shape, mesh, ("data",))
        csh = SH.named_shardings(csp, mesh)
        batch = SPECS.batch_specs(cfg, shape, mesh, ("data",))
        lowered = jax.jit(serve, in_shardings=(psh, None, csh),
                          out_shardings=(None, csh)).lower(
            pshape, batch, cache_shape)
        compiled = lowered.compile()
        print("DECODE_OK")
""")


def test_small_mesh_dryrun():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=1200,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert "TRAIN_OK" in r.stdout and "DECODE_OK" in r.stdout
