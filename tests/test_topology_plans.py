"""Topology-aware plans: the unified facade over distributed SpMV.

Covers the PR's acceptance criteria that don't need a multi-device
process (those run in tests/test_distributed_spmv.py subprocesses):
  * partitioner plugin registry (duplicates refused, custom partitioners
    participate in planning end-to-end)
  * content keys: a 1-device topology hashes identically to no topology
    (single-device caches never fork); topology/partition are otherwise
    key-relevant
  * sharded plan save/load round-trips (perm + panel starts + operator
    arrays, pid.tid tmp+rename discipline) with zero re-tune
  * ShardedOperator correctness on the simulated single-device path for
    every layout x partitioner x engine, SpMM included, permuted opt-out
  * the joint (partition x scheme) selection reacts to structure
  * the "parallel" experiment cell kind: campaign through Runner +
    ResultStore, 100% store hits on re-run
  * SpmvService sharded-key registration (original-index-space requests)
"""
import glob
import os
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import (PARTITIONER_REGISTRY, Plan, ShardedOperator,
                       SpmvProblem, Topology, plan, plan_key,
                       register_partitioner)
from repro.core.sparse.partition import nnz_balanced_partition
from repro.matrices import generators as G


@pytest.fixture()
def stores(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    monkeypatch.setenv("REPRO_REORDER_CACHE", str(tmp_path / "reorder"))
    monkeypatch.setenv("REPRO_OPERATOR_CACHE", str(tmp_path / "opcache"))
    monkeypatch.setenv("REPRO_RESULT_STORE", str(tmp_path / "results"))
    return tmp_path


def _mat(m=192, seed=0):
    return G.shuffle(G.banded(m, 3, seed=seed), seed=seed + 1)


def _oracle_check(op, mat, k=0, tol=1e-5, seed=0):
    rng = np.random.default_rng(seed)
    if k:
        x = rng.standard_normal((mat.n, k))
        want = mat.to_dense() @ x
        got = np.asarray(op.matmul(jnp.asarray(x, jnp.float32)))
    else:
        x = rng.standard_normal(mat.n)
        want = mat.spmv(x)
        got = np.asarray(op(jnp.asarray(x, jnp.float32)))
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < tol, err


# -- Topology type ---------------------------------------------------------

def test_topology_validation():
    assert Topology(devices=1).trivial
    t = Topology(devices=8, layout="2d_panels")
    assert t.mesh_shape == (4, 2) and t.mesh_axes == ("data", "model")
    assert Topology(devices=6, layout="1d_rows").mesh_shape == (6,)
    with pytest.raises(ValueError):
        Topology(devices=0)
    with pytest.raises(ValueError):
        Topology(devices=4, layout="3d_torus")
    with pytest.raises(ValueError):
        Topology(devices=4, layout="2d_panels", mesh_shape=(3, 2))
    with pytest.raises(ValueError):
        Topology(devices=4, layout="1d_rows", mesh_shape=(2, 2))
    # json round trip
    t2 = Topology.from_json(t.to_json())
    assert t2 == t


# -- content keys ----------------------------------------------------------

def test_one_device_topology_key_equals_plain_key(stores):
    """Satellite: a 1-device topology must hash to the SAME plan key as
    no topology, so single-device caches never fork."""
    mat = _mat()
    p = SpmvProblem(mat)
    k_plain = plan_key(p, "rcm", "csr", False, 0)
    k_triv = plan_key(p, "rcm", "csr", False, 0,
                      topology=Topology(devices=1))
    assert k_plain == k_triv
    pl = plan(p, reorder="rcm", engine="csr",
              topology=Topology(devices=1))
    assert pl.topology is None and pl.key == k_plain
    # and the stored entry is shared: a plain plan() re-request hits it
    pl2 = plan(p, reorder="rcm", engine="csr")
    assert pl2.cache_hit and pl2.key == pl.key


def test_sharded_key_normalizes_probe(stores):
    """Sharded plans are model-based: probe must not fork their store
    entries (probe=True and probe=False hash identically under a
    non-trivial topology, but stay distinct single-device)."""
    mat = _mat()
    p = SpmvProblem(mat)
    topo = Topology(devices=4)
    k_noprobe = plan_key(p, "rcm", "csr", False, 0, topology=topo,
                         partition="static", partitioners=["static"])
    k_probe = plan_key(p, "rcm", "csr", True, 0, topology=topo,
                       partition="static", partitioners=["static"])
    assert k_noprobe == k_probe
    assert plan_key(p, "rcm", "csr", True, 0) != \
        plan_key(p, "rcm", "csr", False, 0)


def test_topology_and_partition_are_key_relevant(stores):
    mat = _mat()
    p = SpmvProblem(mat)
    base = plan_key(p, "rcm", "csr", False, 0)
    keys = {
        plan_key(p, "rcm", "csr", False, 0, topology=Topology(devices=4),
                 partition="static", partitioners=["static"]),
        plan_key(p, "rcm", "csr", False, 0, topology=Topology(devices=8),
                 partition="static", partitioners=["static"]),
        plan_key(p, "rcm", "csr", False, 0,
                 topology=Topology(devices=8, layout="2d_panels"),
                 partition="static", partitioners=["static"]),
        plan_key(p, "rcm", "csr", False, 0, topology=Topology(devices=8),
                 partition="nnz_balanced",
                 partitioners=["nnz_balanced"]),
    }
    assert len(keys) == 4 and base not in keys


# -- partitioner registry --------------------------------------------------

def test_partitioner_registry_builtins():
    for name in ("static", "nnz_balanced", "chunked_cyclic", "metis_cut"):
        assert name in PARTITIONER_REGISTRY
    assert PARTITIONER_REGISTRY["static"].auto_candidate
    assert PARTITIONER_REGISTRY["nnz_balanced"].auto_candidate
    assert not PARTITIONER_REGISTRY["chunked_cyclic"].auto_candidate
    assert PARTITIONER_REGISTRY["metis_cut"].reorders


def test_partitioner_duplicate_registration_refused():
    with pytest.raises(ValueError):
        @register_partitioner("static")
        def _dup(mat, p, seed=0):           # pragma: no cover
            return None, None


def test_custom_partitioner_participates_in_planning(stores):
    """A just-registered plugin partitioner is immediately selectable —
    and wins partition='auto' when its cost is lowest."""
    name = "test_reversed_static"
    if name not in PARTITIONER_REGISTRY:
        @register_partitioner(name, description="test plugin")
        def _reversed_static(mat, p, seed=0):
            from repro.core.sparse.partition import static_partition

            return (np.arange(mat.m - 1, -1, -1, dtype=np.int64),
                    static_partition(mat, p))

    mat = _mat()
    pl = plan(SpmvProblem(mat), reorder="baseline", engine="csr",
              topology=Topology(devices=4), partition=name)
    assert pl.partitioner == name
    assert pl.perm is not None           # the plugin's grouping perm rode in
    op = pl.build()
    _oracle_check(op, mat)


# -- sharded plans: selection, round-trip, simulated execution -------------

@pytest.mark.parametrize("layout", ["1d_rows", "2d_panels"])
@pytest.mark.parametrize("engine", ["bell", "csr"])
def test_sharded_operator_simulated_oracle(layout, engine, stores):
    mat = _mat()
    pl = plan(SpmvProblem(mat), reorder="rcm", engine=engine,
              topology=Topology(devices=4, layout=layout),
              partition="nnz_balanced")
    op = pl.build()
    assert isinstance(op, ShardedOperator)
    assert op.simulated                   # 1-device pytest process
    assert op.topology.layout == layout
    _oracle_check(op, mat)
    _oracle_check(op, mat, k=3)           # SpMM path
    # permuted opt-out: reordered-space in, reordered-space out
    rmat = pl.reordered_matrix()
    xr = np.random.default_rng(3).standard_normal(mat.n)
    got = np.asarray(op(jnp.asarray(xr, jnp.float32), permuted=True))
    want = rmat.spmv(xr)
    assert np.abs(got - want).max() / (np.abs(want).max() + 1e-9) < 1e-5
    # unwrap() is the permuted-space view harnesses time
    got2 = np.asarray(op.unwrap()(jnp.asarray(xr, jnp.float32)))
    assert np.array_equal(got, got2)


@pytest.mark.parametrize("partition",
                         ["static", "nnz_balanced", "chunked_cyclic_c16",
                          "metis_cut"])
def test_every_partitioner_plans_and_executes(partition, stores):
    mat = G.power_law(256, alpha=1.8, seed=2)
    pl = plan(SpmvProblem(mat), reorder="baseline", engine="csr",
              topology=Topology(devices=4), partition=partition)
    assert pl.partitioner == partition
    assert pl.panel_starts.size == 5
    _oracle_check(pl.build(), mat, tol=1e-4)


def test_sharded_roundtrip_zero_retune(stores):
    """Acceptance: save -> load -> build reuses the stored layout arrays
    (no re-partition/re-conversion) and pays zero plan time."""
    mat = _mat(256)
    pl = plan(SpmvProblem(mat, k=4), reorder="rcm", engine="auto",
              topology=Topology(devices=8), partition="auto")
    op = pl.build()                       # persists the operator payload
    pl2 = Plan.load(pl.key, mat=mat)
    assert pl2 is not None and pl2.cache_hit
    assert pl2.plan_ms == 0.0 and pl2.tune_ms == 0.0 \
        and pl2.reorder_ms == 0.0
    assert pl2.partitioner == pl.partitioner
    assert pl2.topology == pl.topology
    assert np.array_equal(pl2.panel_starts, pl.panel_starts)
    assert pl2.comm == pl.comm
    op2 = pl2.build()
    assert op2.build_info["cache_hit"] and op2.build_info["build_ms"] == 0.0
    x = np.random.default_rng(0).standard_normal(mat.n)
    assert np.array_equal(np.asarray(op(jnp.asarray(x, jnp.float32))),
                          np.asarray(op2(jnp.asarray(x, jnp.float32))))
    # a fresh plan() request for the same problem is a pure cache hit too
    pl3 = plan(SpmvProblem(mat, k=4), reorder="rcm", engine="auto",
               topology=Topology(devices=8), partition="auto")
    assert pl3.cache_hit


def test_sharded_store_write_discipline(stores):
    """Satellite: sharded entries follow the shared pid.tid tmp+rename
    convention — no orphaned tmp files, npz+json pairs only."""
    mat = _mat()
    pl = plan(SpmvProblem(mat), reorder="rcm", engine="csr",
              topology=Topology(devices=4), partition="static")
    pl.build()
    d = str(stores / "plans")
    assert not glob.glob(os.path.join(d, "*.tmp"))
    assert os.path.exists(os.path.join(d, pl.key + ".json"))
    assert os.path.exists(os.path.join(d, pl.key + ".npz"))
    z = np.load(os.path.join(d, pl.key + ".npz"))
    assert "panel_starts" in z.files      # plan-level split
    assert any(k.startswith("op__") for k in z.files)   # operator payload


def test_joint_partition_selection_prefers_balance_on_skew(stores):
    """partition='auto' on a skewed matrix picks nnz_balanced over static
    (the LI term dominates); the per-candidate costs are recorded."""
    mat = G.power_law(512, alpha=1.6, seed=0)
    pl = plan(SpmvProblem(mat), reorder="baseline", engine="csr",
              topology=Topology(devices=8), partition="auto")
    assert pl.partitioner == "nnz_balanced", pl.partition_costs
    assert any(key.startswith("baseline+static")
               for key in pl.partition_costs)
    st = nnz_balanced_partition(pl.reordered_matrix(), 8)
    assert np.array_equal(pl.panel_starts, st)


def test_sharded_plan_rejects_bad_requests(stores):
    mat = _mat()
    with pytest.raises(ValueError):      # engine outside the panel set
        plan(SpmvProblem(mat), reorder="baseline", engine="sell",
             topology=Topology(devices=4))
    rect = G.banded(64, 2, seed=0)
    rect = rect.__class__(rowptr=rect.rowptr, cols=rect.cols,
                          vals=rect.vals, shape=(64, 128))
    with pytest.raises(ValueError):      # non-square
        plan(SpmvProblem(rect), reorder="baseline",
             topology=Topology(devices=4))
    with pytest.raises(KeyError):        # unknown partitioner
        plan(SpmvProblem(mat), reorder="baseline",
             topology=Topology(devices=4), partition="nope")


def test_cg_through_sharded_operator(stores):
    from repro.core.measure import cg
    from repro.core.sparse.csr import CSRMatrix

    dense = G.banded(128, 3, seed=1).to_dense()
    dense = (dense + dense.T) / 2 + 6.0 * np.eye(128)
    r, c = np.nonzero(dense)
    spd = CSRMatrix.from_coo(r, c, dense[r, c], (128, 128))
    b = np.random.default_rng(0).standard_normal(128)
    res, op = cg.solve_problem(spd, jnp.asarray(b, jnp.float32),
                               reorder="rcm", engine="csr", max_iter=300,
                               tol=1e-6, topology=Topology(devices=4),
                               partition="nnz_balanced")
    assert isinstance(op, ShardedOperator)
    x = np.asarray(res.x, np.float64)
    assert np.abs(spd.spmv(x) - b).max() < 1e-3


# -- the "parallel" experiment cell kind -----------------------------------

def test_parallel_cell_kind_campaign_resumes(stores):
    from repro.experiments import (ExperimentSpec, MeasurePolicy,
                                   ResultStore, Runner)
    from repro.experiments.cells import parallel_variant

    spec = ExperimentSpec(
        name="t_par", matrices=("smoke_banded", "smoke_powerlaw"),
        schemes=("baseline", "rcm"), engines=("csr",), ps=(4,),
        kind="parallel",
        variants=(parallel_variant("1d_rows", "nnz_balanced"),),
        policy=MeasurePolicy(iters=2, warmup=0, verify=True,
                             with_yax=False, with_parallel=False,
                             with_metrics=False))
    store = ResultStore()
    rep = Runner(spec, store=store, verbose=False).run()
    assert rep.measured == 4 and rep.reused == 0
    for rec in rep.records:
        assert rec["partitioner"] == "nnz_balanced"
        assert rec["comm_schedule"] in ("all_gather", "halo")
        assert rec["comm_bytes_per_spmv"] > 0
        assert rec["li"] >= 1.0
        assert rec["verify_rel_err"] < 1e-4
        assert rec["modelled_par_ms"] > 0
        assert rec["simulated"]          # 1-device pytest process
    # resumability: identical spec re-run measures nothing
    rep2 = Runner(spec, store=store, verbose=False).run()
    assert rep2.measured == 0 and rep2.reused == 4
    # the scheme axis is honored: rcm cells see reduced cut on banded
    cut_base = rep2.cell("smoke_banded", "baseline")["cut_volume"]
    cut_rcm = rep2.cell("smoke_banded", "rcm")["cut_volume"]
    assert cut_rcm <= cut_base


def test_parallel_cell_kind_rejects_single_device(stores):
    from repro.experiments import Cell, MeasurePolicy
    from repro.experiments.cells import measure_parallel_cell

    pol = tuple(sorted(MeasurePolicy().resolve("").items()))
    cell = Cell(kind="parallel", matrix="<adhoc>", scheme="baseline",
                engine="csr", dtype="float32", p=1, k=1,
                variant="1d_rows:static", policy=pol)
    with pytest.raises(ValueError, match="p >= 2"):
        measure_parallel_cell(cell, _mat())


# -- service: sharded keys -------------------------------------------------

def test_service_sharded_key_original_space(stores):
    from repro.serving.spmv_service import SpmvService

    mat = _mat(160)
    rng = np.random.default_rng(5)
    with SpmvService(engine="csr", reorder="rcm", max_batch=4,
                     window_ms=2.0) as svc:
        svc.register("plain", mat)
        svc.register("sharded", mat, topology=Topology(devices=4))
        xs = [rng.standard_normal(mat.n) for _ in range(8)]
        futs = [(x, svc.submit("sharded", x)) for x in xs]
        futs += [(x, svc.submit("plain", x)) for x in xs[:2]]
        svc.flush()
        for x, fut in futs:
            want = mat.spmv(x)
            got = np.asarray(fut.result(timeout=10))
            assert np.abs(got - want).max() / \
                (np.abs(want).max() + 1e-9) < 1e-4
        op = svc.operator("sharded")
        assert isinstance(op, ShardedOperator)
        assert op.topology.devices == 4


# -- no shims on the facade path -------------------------------------------

def test_sharded_facade_uses_no_shims(stores):
    from repro.launch.spmv_bench import run_parallel

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        rec = run_parallel("smoke_banded", "rcm", engine="auto", devices=4,
                           layout="2d_panels", partition="nnz_balanced",
                           iters=2, write_results=False)
    assert rec["verify_rel_err"] < 1e-4
    assert rec["devices"] == 4 and rec["layout"] == "2d_panels"
