"""Elastic scaling: a checkpoint written on ONE device resumes on an
8-device mesh (different sharding) and training continues — the re-mesh
path a 1000-node deployment uses after losing/gaining pods.

Subprocess: fake device count must precede jax init."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.configs.base import ModelConfig
    from repro.distributed import sharding as SH
    from repro.training import checkpoint as CKPT, data as DATA
    from repro.training import optimizer as OPT, train_loop as TL

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, kv_heads=2, d_ff=128, vocab=256, head_dim=16)
    opt_cfg = OPT.OptConfig(peak_lr=1e-3, warmup_steps=5, total_steps=40)
    data = DATA.SyntheticLM(DATA.DataConfig(vocab=256, seq_len=64,
                                            global_batch=8))
    tmp = tempfile.mkdtemp()

    # ---- phase 1: single-device training, save at step 20 ----
    step1, _, _ = TL.make_train_step(cfg, opt_cfg, mesh=None, dp_axes=(),
                                     microbatches=1,
                                     compute_dtype=jnp.float32)
    state = TL.init_state(cfg, jax.random.PRNGKey(0))
    jit1 = jax.jit(step1)
    for s in range(20):
        state, m = jit1(state, {k: jnp.asarray(v)
                                for k, v in data.batch(s).items()})
    loss_at_20 = float(m["loss"])
    ck = CKPT.Checkpointer(tmp, async_save=False)
    ck.save(20, state)

    # ---- phase 2: resume on a (2, 4) mesh with FSDP x TP sharding ----
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    step2, sh_fn, _ = TL.make_train_step(cfg, opt_cfg, mesh, ("data",),
                                         microbatches=1,
                                         compute_dtype=jnp.float32)
    restored, _ = ck.restore(20, state)
    st_sh = sh_fn(jax.eval_shape(lambda: restored["params"]))
    with mesh:
        state2 = jax.device_put(restored, st_sh)
        jit2 = jax.jit(step2, donate_argnums=(0,))
        for s in range(20, 40):
            b = jax.device_put({k: jnp.asarray(v)
                                for k, v in data.batch(s).items()},
                               NamedSharding(mesh, P("data", None)))
            state2, m2 = jit2(state2, b)
    loss_at_40 = float(m2["loss"])
    print("ELASTIC_OK", loss_at_20, loss_at_40)
    assert loss_at_40 < loss_at_20 + 0.2, (loss_at_20, loss_at_40)
""")


def test_elastic_remesh_resume():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=1200,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout
