"""The paper's technique inside the LM framework: MoE routing is a sparse
matrix; sorted dispatch = reordering; capacity = the nnz-balanced schedule;
LI (paper §6.1) is reported per step.

    PYTHONPATH=src python examples/moe_reordering.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import moe as MOE

d, tokens = 128, 4096
for e, k in [(16, 2), (64, 8)]:
    cfg = MoEConfig(num_experts=e, top_k=k, d_ff_expert=256)
    params = MOE.init_moe(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, tokens, d), jnp.float32)
    y, m = jax.jit(lambda p, xx: MOE.moe_layer(p, xx, cfg))(params, x)
    print(f"E={e:3d} top-{k}: router LI={float(m['router_li']):.2f} "
          f"(1.0 = perfectly balanced), dropped={float(m['drop_frac']):.3%} "
          f"under capacity (nnz-balanced) schedule, "
          f"aux={float(m['aux_loss']):.3f}")

# The same routing through the Problem->Plan->Operator pipeline
# (repro.workloads): dispatch/combine become registry operators, and a
# value-only stream (routing structure frozen, gates changing) plans once
# per role and then rebuilds/reuses — the paper's amortization question
# answered on workload-shaped sparsity.
from repro.workloads import DynamicSparseProblem, run_stream  # noqa: E402

rec = run_stream(DynamicSparseProblem("workload://moe-e16-k2-t1024-d64-n4",
                                      scenario="static"), iters=2)
print(f"pipeline (E=16 top-2, {rec['steps']}-step value-only stream): "
      f"plans={rec['plans']} replans={rec['replans']} "
      f"reuse rate={rec['reuse_rate']:.0%}, "
      f"plan-cost share={rec['plan_cost_share']:.0%}, "
      f"sorted-vs-onehot speedup={rec['speedup_vs_ref']:.2f}x, "
      f"dispatch bitwise-equal={rec['dispatch_bitwise_equal']}")
assert rec["replans"] == 0 and rec["dispatch_bitwise_equal"]
