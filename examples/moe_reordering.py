"""The paper's technique inside the LM framework: MoE routing is a sparse
matrix; sorted dispatch = reordering; capacity = the nnz-balanced schedule;
LI (paper §6.1) is reported per step.

    PYTHONPATH=src python examples/moe_reordering.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import moe as MOE

d, tokens = 128, 4096
for e, k in [(16, 2), (64, 8)]:
    cfg = MoEConfig(num_experts=e, top_k=k, d_ff_expert=256)
    params = MOE.init_moe(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, tokens, d), jnp.float32)
    y, m = jax.jit(lambda p, xx: MOE.moe_layer(p, xx, cfg))(params, x)
    print(f"E={e:3d} top-{k}: router LI={float(m['router_li']):.2f} "
          f"(1.0 = perfectly balanced), dropped={float(m['drop_frac']):.3%} "
          f"under capacity (nnz-balanced) schedule, "
          f"aux={float(m['aux_loss']):.3f}")
