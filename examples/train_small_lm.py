"""Train a ~100M-param LM for a few hundred steps on CPU, with a mid-run
simulated crash + auto-resume (fault-tolerance demo).

    PYTHONPATH=src python examples/train_small_lm.py [--steps 200] [--small]
"""
import argparse
import dataclasses
import shutil

from repro.launch.train import small_lm_config, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--small", action="store_true",
                help="~1M-param reduced config (CI / quick sanity run)")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--crash-demo", action="store_true",
                help="crash at 40%% and auto-resume")
args = ap.parse_args()

ckpt_dir = "/tmp/repro_example_ckpt"
shutil.rmtree(ckpt_dir, ignore_errors=True)
cfg = small_lm_config()
if args.small:
    cfg = dataclasses.replace(cfg, name="small-lm-ci", n_layers=2,
                              d_model=128, n_heads=4, kv_heads=2,
                              d_ff=256, vocab=512, head_dim=32)
print(f"model: {cfg.param_count()/1e6:.1f}M params")

if args.crash_demo:
    out = train(cfg, args.steps, ckpt_dir, ckpt_every=20,
                batch=args.batch, seq=args.seq,
                crash_at=int(args.steps * 0.4))
    print("crashed:", {k: v for k, v in out.items() if k != 'losses'})
out = train(cfg, args.steps, ckpt_dir, ckpt_every=20,
            batch=args.batch, seq=args.seq)
print(f"loss: {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
      f"over {args.steps} steps")
assert out["final_loss"] < out["first_loss"], "loss must decrease"
