"""Conjugate-Gradient solve — the paper's "real application" — with and
without reordering, plus the Pallas Block-ELL engine (interpret mode).

    PYTHONPATH=src python examples/cg_solver.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.measure import cg
from repro.core.reorder import api as reorder
from repro.core.spmv.ops import build_operator
from repro.matrices import generators as G

mat = G.shuffle(G.stencil_2d(120, seed=0), seed=1)  # 14.4k-node Laplacian
rng = np.random.default_rng(0)
x_true = rng.standard_normal(mat.n)
b = jnp.asarray(mat.spmv(x_true), jnp.float32)

for scheme in ["baseline", "rcm"]:
    perm = reorder.reorder(mat, scheme)
    rmat = mat.permute(perm) if scheme != "baseline" else mat
    b_perm = jnp.asarray(np.asarray(b)[perm]) if scheme != "baseline" else b
    op = build_operator(rmat, "csr")
    t0 = time.time()
    res = cg.cg_solve(op, b_perm, max_iter=300, tol=1e-5)
    dt = time.time() - t0
    # undo the permutation on the solution and check the ORIGINAL system
    x = np.asarray(res.x)
    if scheme != "baseline":
        un = np.empty_like(x)
        un[perm] = x
        x = un
    err = np.abs(mat.spmv(x) - np.asarray(b)).max()
    print(f"{scheme:9s} iters={int(res.iters):4d} residual={float(res.residual):.2e} "
          f"check={err:.2e} wall={dt:.2f}s")

# the Pallas Block-ELL engine agrees with CSR (interpret mode, 1 SpMV)
op_bell = build_operator(mat, "bell", block_shape=(8, 16), use_kernel="interpret")
y_bell = np.asarray(op_bell(b))
y_csr = np.asarray(build_operator(mat, "csr")(b))
err = np.abs(y_bell - y_csr).max() / (np.abs(y_csr).max() + 1e-9)
print(f"bell kernel (interpret) vs csr: max rel err {err:.2e}")
