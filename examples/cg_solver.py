"""Conjugate-Gradient solve — the paper's "real application" — with and
without reordering, through the Problem -> Plan -> Operator pipeline.

The permutation-carrying operator keeps the WHOLE solve in the original
index space: no permuting b before the solve, no un-permuting x after —
the two hand-carried gathers the old wiring needed are gone.

    PYTHONPATH=src python examples/cg_solver.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.api import SpmvProblem, plan
from repro.core.measure import cg
from repro.matrices import generators as G

mat = G.shuffle(G.stencil_2d(120, seed=0), seed=1)  # 14.4k-node Laplacian
rng = np.random.default_rng(0)
x_true = rng.standard_normal(mat.n)
b = jnp.asarray(mat.spmv(x_true), jnp.float32)

for scheme in ["baseline", "rcm"]:
    t0 = time.time()
    res, op = cg.solve_problem(mat, b, reorder=scheme, engine="csr",
                               max_iter=300, tol=1e-5)
    dt = time.time() - t0
    # res.x is already in the original index space: check A x = b directly
    x = np.asarray(res.x)
    err = np.abs(mat.spmv(x) - np.asarray(b)).max()
    print(f"{scheme:9s} iters={int(res.iters):4d} residual={float(res.residual):.2e} "
          f"check={err:.2e} wall={dt:.2f}s")

# the Pallas Block-ELL engine agrees with CSR (interpret mode, 1 SpMV) —
# on a smaller grid: interpret mode simulates the kernel step by step, so
# the 14.4k-node system would take minutes for this one sanity multiply
small = G.stencil_2d(32, seed=0)
bs = jnp.asarray(small.spmv(rng.standard_normal(small.n)), jnp.float32)
pb = SpmvProblem(small, hints={"block_shape": (8, 16),
                               "use_kernel": "interpret"})
op_bell = plan(pb, reorder="baseline", engine="bell").build()
op_csr = plan(SpmvProblem(small), reorder="baseline", engine="csr").build()
y_bell = np.asarray(op_bell(bs))
y_csr = np.asarray(op_csr(bs))
err = np.abs(y_bell - y_csr).max() / (np.abs(y_csr).max() + 1e-9)
print(f"bell kernel (interpret) vs csr: max rel err {err:.2e}")
