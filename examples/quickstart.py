"""Quickstart: reorder a sparse matrix and measure SpMV under IOS.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.measure import ios
from repro.core.reorder import api as reorder
from repro.core.sparse import metrics, partition
from repro.core.spmv.ops import build_operator
from repro.matrices import generators as G

# a shuffled banded matrix: structure exists but is hidden (paper Fig. 1)
mat = G.shuffle(G.banded(100_000, 8, seed=0), seed=1)
x = jnp.asarray(np.random.default_rng(0).standard_normal(mat.n), jnp.float32)

print(f"matrix: {mat.m}x{mat.n}, nnz={mat.nnz}, "
      f"bandwidth={metrics.bandwidth(mat)}")

for scheme in ["baseline", "rcm", "metis", "louvain", "patoh"]:
    perm = reorder.reorder(mat, scheme)
    rmat = mat.permute(perm) if scheme != "baseline" else mat
    # engine="auto": the OSKI-style tuner (DESIGN.md "Engine selection &
    # autotuning") picks the format per reordered matrix
    op = build_operator(rmat, "auto")
    ms = float(np.median(ios.run_ios(op, x, iters=8)))
    panels = partition.static_partition(rmat, 8)
    print(f"{scheme:10s} engine={op.plan.label():14s} ios={ms:7.2f}ms "
          f"gflops={ios.gflops(rmat.nnz, np.array([ms]))[0]:5.2f} "
          f"bandwidth={metrics.bandwidth(rmat):7d} "
          f"LI(8)={metrics.load_imbalance(rmat, panels):.3f} "
          f"cut(8)={metrics.cut_volume(rmat, panels):8d}")
