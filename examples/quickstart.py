"""Quickstart: the Problem -> Plan -> Operator pipeline (repro.api).

One staged call replaces the old reorder/build/tune wiring: `plan()` picks
the (scheme, engine, shape) jointly, `Plan.build()` returns an operator
that CARRIES its permutation — `op(x)` takes x in the original index
space, so nothing here permutes vectors by hand.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.api import SpmvProblem, plan
from repro.core.measure import ios
from repro.core.sparse import metrics, partition
from repro.matrices import generators as G

# a shuffled banded matrix: structure exists but is hidden (paper Fig. 1)
mat = G.shuffle(G.banded(100_000, 8, seed=0), seed=1)
x = jnp.asarray(np.random.default_rng(0).standard_normal(mat.n), jnp.float32)
want = mat.spmv(np.asarray(x))

print(f"matrix: {mat.m}x{mat.n}, nnz={mat.nnz}, "
      f"bandwidth={metrics.bandwidth(mat)}")

problem = SpmvProblem(mat)
for scheme in ["baseline", "rcm", "metis", "louvain", "patoh", "auto"]:
    # engine="auto": the OSKI-style tuner (DESIGN.md "Engine selection &
    # autotuning") picks the format per reordered matrix; scheme "auto"
    # additionally searches the reordering axis (joint selection)
    pl = plan(problem, reorder=scheme, engine="auto")
    op = pl.build()
    # the operator accepts x in the ORIGINAL index space — verify it
    err = float(np.abs(np.asarray(op(x)) - want).max() / np.abs(want).max())
    assert err < 1e-4, (scheme, err)
    # measurement opts out of the permutation wrapper (reordered space)
    ms = float(np.median(ios.run_ios(op.unwrap(), x, iters=8)))
    rmat = pl.reordered_matrix()
    panels = partition.static_partition(rmat, 8)
    print(f"{scheme:10s} plan={pl.label():22s} ios={ms:7.2f}ms "
          f"gflops={ios.gflops(rmat.nnz, np.array([ms]))[0]:5.2f} "
          f"bandwidth={metrics.bandwidth(rmat):7d} "
          f"LI(8)={metrics.load_imbalance(rmat, panels):.3f} "
          f"cut(8)={metrics.cut_volume(rmat, panels):8d} err={err:.1e}")
