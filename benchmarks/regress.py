#!/usr/bin/env python
"""Perf-regression gate CLI (thin wrapper over repro.experiments.regress).

    PYTHONPATH=src python benchmarks/regress.py \
        --baseline benchmarks/baseline/BENCH_spmv.json \
        --current BENCH_spmv.json

Exit 0 = pass, 1 = regression beyond tolerance, 2 = incomparable
(scale stamps differ / unreadable summary). Defaults compare the
committed baseline against the repo-root BENCH_spmv.json.
"""
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_HERE, "..", "src"))

from repro.experiments.regress import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--baseline" not in argv:
        argv += ["--baseline",
                 os.path.join(_HERE, "baseline", "BENCH_spmv.json")]
    if "--current" not in argv:
        argv += ["--current", os.path.join(_HERE, "..", "BENCH_spmv.json")]
    raise SystemExit(main(argv))
