"""SpMM batch-width sweep: does reordering's benefit grow or shrink with k?

For k ∈ {1, 2, 4, 8, 16, 32} RHS vectors, time `op.matmul(X[n, k])` under
the IOS protocol for each (matrix, scheme, engine) cell and report the
amortized time-per-vector. Two questions:

  * amortization — per-vector time should fall with k (the matrix stream
    and dispatch overhead are paid once per SpMM), fastest for the SELL
    engine whose k-tiled kernel reuses each chunk across the vector tile;
  * reordering × batching — reordering's speedup comes from x-gather
    locality, whose share of total traffic shrinks as matrix bytes
    amortize, so the rcm-vs-baseline ratio is expected to move with k
    (the hypergraph locality models' prediction; CSV column
    `speedup_vs_baseline`).

    PYTHONPATH=src python -m benchmarks.spmm_batch [--quick | --smoke]

Writes benchmarks/results/spmm_batch.csv.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.api import SpmvProblem, plan
from repro.core.measure import ios
from repro.matrices import suite

from .common import RESULTS_DIR, write_csv

K_SWEEP = [1, 2, 4, 8, 16, 32]
ENGINES = ["sell", "csr", "auto"]
SCHEMES = ["baseline", "rcm"]

FULL_MATRICES = ["powerlaw_m16384_a21", "banded_shuf_m16384_bw8",
                 "stencil2d_shuf_128", "smallworld_m16384_k6"]
QUICK_MATRICES = ["powerlaw_m16384_a21", "banded_shuf_m16384_bw8"]
SMOKE_MATRICES = ["smoke_powerlaw", "smoke_banded"]


def _measure_cell(mat, scheme: str, engine: str, k: int, iters: int) -> dict:
    """One plan() + build() per cell through the pipeline facade; the plan
    store makes repeat sweeps free (fixed-engine entries are shared across
    the k axis — k only specializes engine="auto" plans)."""
    pl = plan(SpmvProblem(mat, k=k), reorder=scheme, engine=engine)
    op = pl.build()
    # time the bare reordered-space engine (permutation wrapper opted out)
    ms = float(np.median(ios.run_ios_batched(op.unwrap(), mat.n, k,
                                             iters=iters, warmup=2)))
    return {
        "engine": op.build_info["engine"],
        "plan_label": pl.tune.label(),    # k-specialized label, e.g. csr@k8
        "spmm_ms": ms,
        "per_vector_ms": ms / k,
        "gflops": float(ios.gflops(mat.nnz * k, np.array([ms]))[0]),
    }


def run(quick: bool = True, smoke: bool = False, iters: int | None = None) -> dict:
    matrices = SMOKE_MATRICES if smoke else (
        QUICK_MATRICES if quick else FULL_MATRICES)
    iters = iters if iters is not None else (3 if smoke else 6)
    # smoke must still span k values ABOVE the SELL k-tile floor (8), so
    # the decreasing-per-vector gate reflects real amortization, not just
    # tile padding
    ks = [1, 2, 8, 32] if smoke else K_SWEEP

    rows = []
    cells = {}
    for mname in matrices:
        mat = suite.get(mname)
        for scheme in SCHEMES:
            for engine in ENGINES:
                for k in ks:
                    rec = _measure_cell(mat, scheme, engine, k, iters)
                    cells[(mname, scheme, engine, k)] = rec
                    rows.append([mname, scheme, engine, rec["engine"],
                                 rec["plan_label"], k,
                                 f"{rec['spmm_ms']:.4f}",
                                 f"{rec['per_vector_ms']:.4f}",
                                 f"{rec['gflops']:.3f}", ""])
    # speedup_vs_baseline: same (matrix, engine, k), scheme vs baseline
    for i, row in enumerate(rows):
        mname, scheme, engine, k = row[0], row[1], row[2], row[5]
        base = cells.get((mname, "baseline", engine, k))
        if base and scheme != "baseline":
            rows[i][-1] = f"{base['spmm_ms'] / cells[(mname, scheme, engine, k)]['spmm_ms']:.3f}"

    path = os.path.join(RESULTS_DIR, "spmm_batch.csv")
    write_csv(path, ["matrix", "scheme", "engine", "resolved_engine",
                     "plan_label", "k", "spmm_ms", "per_vector_ms", "gflops",
                     "speedup_vs_baseline"], rows)

    # derived summary: amortization ratio per engine (k=1 per-vec time over
    # widest-k per-vec time, >1 means batching pays), plus the sell check
    # the acceptance criterion names
    kmax = ks[-1]
    derived = {"csv": path, "k_sweep": ks, "matrices": matrices}
    for engine in ENGINES:
        ratios = []
        for mname in matrices:
            for scheme in SCHEMES:
                c1 = cells.get((mname, scheme, engine, 1))
                ck = cells.get((mname, scheme, engine, kmax))
                if c1 and ck:
                    ratios.append(c1["per_vector_ms"] / ck["per_vector_ms"])
        if ratios:
            derived[f"{engine}_amortization_x"] = round(
                float(np.median(ratios)), 2)
    sell1 = [cells[(m, s, "sell", 1)]["per_vector_ms"]
             for m in matrices for s in SCHEMES]
    sellk = [cells[(m, s, "sell", kmax)]["per_vector_ms"]
             for m in matrices for s in SCHEMES]
    derived["sell_per_vec_decreases"] = bool(
        np.median(sellk) < np.median(sell1))
    return derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI pass on the smoke matrices")
    args = ap.parse_args()
    derived = run(quick=args.quick, smoke=args.smoke)
    print(derived)
    if not derived.get("sell_per_vec_decreases", False):
        raise SystemExit("amortized per-vector time did not decrease with k "
                         "for the SELL engine")


if __name__ == "__main__":
    main()
