"""SpMM batch-width sweep: does reordering's benefit grow or shrink with k?

For k ∈ {1, 2, 4, 8, 16, 32} RHS vectors, time `op.matmul(X[n, k])` under
the IOS protocol for each (matrix, scheme, engine) cell and report the
amortized time-per-vector. Two questions:

  * amortization — per-vector time should fall with k (the matrix stream
    and dispatch overhead are paid once per SpMM), fastest for the SELL
    engine whose k-tiled kernel reuses each chunk across the vector tile;
  * reordering × batching — reordering's speedup comes from x-gather
    locality, whose share of total traffic shrinks as matrix bytes
    amortize, so the rcm-vs-baseline ratio is expected to move with k
    (the hypergraph locality models' prediction; CSV column
    `speedup_vs_baseline`).

A spec with explicit engine and k axes (timing-only policy); the result
store makes repeat sweeps free and extending the k axis incremental.

    PYTHONPATH=src python -m benchmarks.spmm_batch [--quick | --smoke]

Writes benchmarks/results/spmm_batch.csv.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.experiments import ExperimentSpec, MeasurePolicy

from . import common
from .common import RESULTS_DIR, write_csv

K_SWEEP = (1, 2, 4, 8, 16, 32)
ENGINES = ("sell", "csr", "auto")
SCHEMES = ("baseline", "rcm")

FULL_MATRICES = ("powerlaw_m16384_a21", "banded_shuf_m16384_bw8",
                 "stencil2d_shuf_128", "smallworld_m16384_k6")
QUICK_MATRICES = ("powerlaw_m16384_a21", "banded_shuf_m16384_bw8")
SMOKE_MATRICES = ("smoke_powerlaw", "smoke_banded")


def spec(quick: bool = True, smoke: bool = False,
         iters: int | None = None) -> ExperimentSpec:
    matrices = SMOKE_MATRICES if smoke else (
        QUICK_MATRICES if quick else FULL_MATRICES)
    # smoke must still span k values ABOVE the SELL k-tile floor (8), so
    # the decreasing-per-vector gate reflects real amortization, not just
    # tile padding
    ks = (1, 2, 8, 32) if smoke else K_SWEEP
    return ExperimentSpec(
        name="spmm_batch", matrices=matrices, schemes=SCHEMES,
        engines=ENGINES, ks=ks,
        policy=MeasurePolicy(
            iters=iters if iters is not None else (3 if smoke else 6),
            warmup=2, with_yax=False, with_parallel=False,
            with_metrics=False))


def run(quick: bool = True, smoke: bool = False,
        iters: int | None = None) -> dict:
    sp = spec(quick=quick, smoke=smoke, iters=iters)
    rep = common.campaign_report(sp)
    matrices, ks = sp.matrices, sp.ks

    rows = []
    cells = {}
    for mname in matrices:
        for scheme in SCHEMES:
            for engine in ENGINES:
                for k in ks:
                    rec = rep.cell(mname, scheme, engine=engine, k=k)
                    cells[(mname, scheme, engine, k)] = rec
                    gflops = rec.get("spmm_gflops", rec["seq_ios_gflops"]
                                     if k == 1 else None)
                    rows.append([mname, scheme, engine, rec["engine"],
                                 rec["plan_label"], k,
                                 f"{rec['spmm_ms']:.4f}",
                                 f"{rec['per_vector_ms']:.4f}",
                                 f"{gflops:.3f}", ""])
    # speedup_vs_baseline: same (matrix, engine, k), scheme vs baseline
    for i, row in enumerate(rows):
        mname, scheme, engine, k = row[0], row[1], row[2], row[5]
        base = cells.get((mname, "baseline", engine, k))
        if base and scheme != "baseline":
            ratio = base["spmm_ms"] / cells[(mname, scheme, engine, k)]["spmm_ms"]
            rows[i][-1] = f"{ratio:.3f}"

    path = os.path.join(RESULTS_DIR, "spmm_batch.csv")
    write_csv(path, ["matrix", "scheme", "engine", "resolved_engine",
                     "plan_label", "k", "spmm_ms", "per_vector_ms", "gflops",
                     "speedup_vs_baseline"], rows)

    # derived summary: amortization ratio per engine (k=1 per-vec time over
    # widest-k per-vec time, >1 means batching pays), plus the sell check
    # the acceptance criterion names
    kmax = ks[-1]
    derived = {"csv": path, "k_sweep": list(ks), "matrices": list(matrices)}
    for engine in ENGINES:
        ratios = []
        for mname in matrices:
            for scheme in SCHEMES:
                c1 = cells.get((mname, scheme, engine, 1))
                ck = cells.get((mname, scheme, engine, kmax))
                if c1 and ck:
                    ratios.append(c1["per_vector_ms"] / ck["per_vector_ms"])
        if ratios:
            derived[f"{engine}_amortization_x"] = round(
                float(np.median(ratios)), 2)
    sell1 = [cells[(m, s, "sell", 1)]["per_vector_ms"]
             for m in matrices for s in SCHEMES]
    sellk = [cells[(m, s, "sell", kmax)]["per_vector_ms"]
             for m in matrices for s in SCHEMES]
    derived["sell_per_vec_decreases"] = bool(
        np.median(sellk) < np.median(sell1))
    return derived


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI pass on the smoke matrices")
    args = ap.parse_args()
    derived = run(quick=args.quick, smoke=args.smoke)
    print(derived)
    if not derived.get("sell_per_vec_decreases", False):
        raise SystemExit("amortized per-vector time did not decrease with k "
                         "for the SELL engine")


if __name__ == "__main__":
    main()
