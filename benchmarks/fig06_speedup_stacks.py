"""Paper Fig. 6: stacked speedup-bucket counts per scheme (vs baseline),
sequential (measured) + parallel (modelled). Key paper claim: in the
sequential case every scheme except RCM slows down >50% of matrices.
A pure view over the locality campaign."""
from __future__ import annotations

from repro.core.measure import profiles
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv


def run(quick: bool = False):
    mats = suite.locality_names()
    rep = common.campaign_report(common.locality_spec())
    schemes = [s for s in common.SCHEMES if s != "baseline"]
    rows, out = [], {}
    for mode, field in [("sequential", "seq_ios_gflops"),
                        ("parallel_modelled", "par_static_gflops")]:
        sp = rep.speedup(field, mats, schemes)
        counts = profiles.speedup_buckets(sp)
        for i, s in enumerate(schemes):
            for lbl, c in zip(profiles.BUCKET_LABELS, counts[i]):
                rows.append([mode, s, lbl, int(c)])
            out[f"{mode}_{s}_slowdown_frac"] = round(
                float((sp[i] < 1.0).mean()), 3)
    write_csv(f"{RESULTS_DIR}/fig06_speedup_stacks.csv",
              ["mode", "scheme", "bucket", "count"], rows)
    return out
