"""Paper Fig. 6: stacked speedup-bucket counts per scheme (vs baseline),
sequential (measured) + parallel (modelled). Key paper claim: in the
sequential case every scheme except RCM slows down >50% of matrices."""
from __future__ import annotations

import numpy as np

from repro.core.measure import profiles
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, grid, write_csv


def run(quick: bool = False):
    mats = suite.locality_names()
    records = common.run_campaign(matrices=mats, schemes=common.SCHEMES,
                                  profiles=(common.PRIMARY,), tag="locality")
    schemes = [s for s in common.SCHEMES if s != "baseline"]
    rows, out = [], {}
    for mode, field in [("sequential", "seq_ios_gflops"),
                        ("parallel_modelled", "par_static_gflops")]:
        perf = grid(records, common.PRIMARY, mats, common.SCHEMES, field)
        base = perf[common.SCHEMES.index("baseline")]
        sp = perf[[common.SCHEMES.index(s) for s in schemes]] / base
        counts = profiles.speedup_buckets(sp)
        for i, s in enumerate(schemes):
            for lbl, c in zip(profiles.BUCKET_LABELS, counts[i]):
                rows.append([mode, s, lbl, int(c)])
            out[f"{mode}_{s}_slowdown_frac"] = round(
                float((sp[i] < 1.0).mean()), 3)
    write_csv(f"{RESULTS_DIR}/fig06_speedup_stacks.csv",
              ["mode", "scheme", "bucket", "count"], rows)
    return out
