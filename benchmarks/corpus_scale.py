"""Corpus-scale campaign — the first `representative: true` scale stamp.

    PYTHONPATH=src python -m benchmarks.corpus_scale            # full
    PYTHONPATH=src python -m benchmarks.corpus_scale --smoke    # CI gate

Two phases over real-corpus matrices (`corpus://` names resolved through
repro.corpus — SuiteSparse downloads when the network allows, manifest-
shaped synthetic stand-ins offline, either way >= 100k rows so the
summary's scale stamp is `representative: true`):

  1. seed    — probe=True: the empirical tuner measures its top
               candidates and each cell records the structural feature
               vector + the decision that won (the advisor's training
               pairs land in the result store as a side effect).
  2. learned — probe="learned": the TuneAdvisor nearest-neighbor
               shortlist replaces the model ranking, so the tuner times
               strictly fewer candidates per cell.

The learned phase writes BENCH_corpus_scale.json — the corpus-scale
regression-gate baseline (benchmarks/baseline/BENCH_corpus_scale.json is
the committed copy; benchmarks/regress.py compares them).

--smoke is the network-free CI gate on the bundled fixtures: double
ingest (second pass must be a 100% .csrz cache hit — zero parses), an
exhaustive-probe seed campaign, then the learned campaign, asserting the
advisor counters move, every learned cell probes STRICTLY fewer
candidates than its exhaustive twin, and the learned pick's exhaustively
probed time is within 5% of the exhaustive best (GFLOPs-equivalent).
"""
from __future__ import annotations

import argparse
import json
import os

from repro.experiments import ExperimentSpec, MeasurePolicy

from . import common

# both >= 100k rows (REPRESENTATIVE_MIN_M) even as offline stand-ins
SCALE_MATRICES = ("corpus://delaunay_n17", "corpus://cage12")
SCALE_SCHEMES = ("baseline", "rcm")

# the 1k-row campaign fixtures: large enough that the empirical probe
# separates engines by structure, not dispatch noise (the 64-96 row parse
# fixtures time pure overhead, which makes a 5% quality gate meaningless)
SMOKE_MATRICES = ("corpus://fix_banded_1k", "corpus://fix_plaw_1k")
SMOKE_SCHEMES = ("baseline", "rcm")

BENCH_CORPUS_PATH = os.path.join(os.path.dirname(__file__), "..",
                                 "BENCH_corpus_scale.json")


def _policy(probe, iters: int, use_kernel: str = "auto") -> MeasurePolicy:
    return MeasurePolicy(iters=iters, warmup=1, probe=probe,
                         with_yax=False, with_parallel=False,
                         with_metrics=False, use_kernel=use_kernel)


def seed_spec(quick: bool = False) -> ExperimentSpec:
    return ExperimentSpec(
        name="corpus_scale_seed", matrices=SCALE_MATRICES,
        schemes=SCALE_SCHEMES, engines=("auto",),
        policy=_policy(True, 4 if quick else 8))


def learned_spec(quick: bool = False) -> ExperimentSpec:
    return ExperimentSpec(
        name="corpus_scale", matrices=SCALE_MATRICES,
        schemes=SCALE_SCHEMES, engines=("auto",),
        policy=_policy("learned", 4 if quick else 8))


def _advisor_reset() -> None:
    # the advisor memoizes its mined knowledge base per store root; the
    # learned phase must see the cells the seed phase just wrote
    from repro.corpus.advisor import advisor_reset

    advisor_reset()


def _probe_counts(rep) -> dict:
    return {(r["matrix"], r["scheme"]):
            (r.get("probed_candidates", 0), r.get("tuner_candidates", 0))
            for r in rep.records}


def run(quick: bool = False):
    """Full corpus-scale pass (offline-safe). Returns the derived dict
    for the benchmarks.run MODULES loop."""
    from repro import obs

    store = common.result_store()
    rep_seed = common.Runner(seed_spec(quick), store=store,
                             verbose=False).run()
    _advisor_reset()
    before = obs.snapshot()["counters"]
    rep = common.Runner(learned_spec(quick), store=store,
                        verbose=False).run()
    after = obs.snapshot()["counters"]

    summary = rep.write_bench_summary(os.path.abspath(BENCH_CORPUS_PATH))
    if not summary["scale"]["representative"]:
        raise RuntimeError(
            f"corpus_scale is the paper-scale campaign but its stamp is "
            f"not representative (max_m={summary['scale']['max_m']})")
    seed_probes = _probe_counts(rep_seed)
    learned_probes = _probe_counts(rep)
    rows = [[m, s, seed_probes[(m, s)][0], learned_probes[(m, s)][0],
             learned_probes[(m, s)][1],
             round(rep.cell(m, s).get("advisor_confidence", 0.0), 4),
             round(rep.cell(m, s).get("seq_ios_gflops", -1.0), 4)]
            for m in SCALE_MATRICES for s in SCALE_SCHEMES]
    common.write_csv(os.path.join(common.RESULTS_DIR, "corpus_scale.csv"),
                     ["matrix", "scheme", "seed_probes", "learned_probes",
                      "candidates", "advisor_confidence", "gflops"], rows)
    return {
        "geomean": summary["geomean"],
        "speedup": summary.get("speedup_vs_baseline", {}),
        "representative": summary["scale"]["representative"],
        "max_m": summary["scale"]["max_m"],
        "advisor": {k.split(".", 1)[1]: after.get(k, 0) - before.get(k, 0)
                    for k in ("advisor.hits", "advisor.misses",
                              "advisor.fallbacks")},
    }


# --------------------------------------------------------------------------
# CI smoke (network-free, fixtures only)
# --------------------------------------------------------------------------
def _ingest_fixtures() -> int:
    """Double-ingest the bundled fixtures; the second pass must resolve
    every matrix from its .csrz artifact (zero parses). Returns failure
    count."""
    from repro import obs
    from repro.corpus import manifest

    names = sorted(n for n, e in manifest.load_manifest().items()
                   if e.fixture)
    failures = 0
    for label in ("cold", "cached"):
        before = obs.snapshot()["counters"].get("corpus.parses", 0)
        for n in names:
            res = manifest.ensure(n, allow_download=False)
            print(f"# ingest[{label}] corpus://{n}: "
                  f"{'hit' if res.cache_hit else 'parsed'} "
                  f"nnz={res.mat.nnz}", flush=True)
        parses = obs.snapshot()["counters"].get("corpus.parses", 0) - before
        if label == "cached" and parses:
            print(f"CACHE-HIT FAILED: re-ingest parsed {parses} matrices "
                  f"(want 0 — every fixture should load from .csrz)",
                  flush=True)
            failures += 1
    return failures


def _exhaustive_probe_table(matrix: str, scheme: str, pol: dict) -> dict:
    """The exhaustive campaign's candidate->measured-ms table for one
    cell, replayed through the plan store (no re-measurement)."""
    from repro.api import SpmvProblem, plan
    from repro.matrices import suite

    hints = {"seed": pol["seed"]}
    if pol["use_kernel"] != "auto":
        hints["use_kernel"] = pol["use_kernel"]
    pl = plan(SpmvProblem(suite.get(matrix), k=1, dtype="float32",
                          hints=hints),
              reorder=scheme, engine="auto", probe="exhaustive")
    return dict(pl.tune.probe_ms or {})


def smoke() -> int:
    """Fixture-scale acceptance gate. Returns failure count."""
    from repro import obs

    failures = _ingest_fixtures()

    exhaustive = ExperimentSpec(
        name="corpus_smoke_seed", matrices=SMOKE_MATRICES,
        schemes=SMOKE_SCHEMES, engines=("auto",),
        policy=_policy("exhaustive", 3))
    learned = ExperimentSpec(
        name="corpus_smoke_learned", matrices=SMOKE_MATRICES,
        schemes=SMOKE_SCHEMES, engines=("auto",),
        policy=_policy("learned", 3))

    store = common.result_store()
    rep_ex = common.Runner(exhaustive, store=store, verbose=False,
                           on_error="record").run()
    failures += len(rep_ex.failures)
    for f in rep_ex.failures:
        print(f"EXHAUSTIVE FAIL {f['label']}: {f['error']}", flush=True)
    if failures:
        return failures

    _advisor_reset()
    before = obs.snapshot()["counters"]
    rep_ln = common.Runner(learned, store=store, verbose=False,
                           on_error="record").run()
    after = obs.snapshot()["counters"]
    failures += len(rep_ln.failures)
    for f in rep_ln.failures:
        print(f"LEARNED FAIL {f['label']}: {f['error']}", flush=True)
    if failures:
        return failures

    ex_probes = _probe_counts(rep_ex)
    pol = learned.policy.resolve("*")
    print("matrix,scheme,exhaustive_probes,learned_probes,confidence,"
          "pick_vs_best", flush=True)
    for m in SMOKE_MATRICES:
        for s in SMOKE_SCHEMES:
            rec = rep_ln.cell(m, s)
            n_ex = ex_probes[(m, s)][0]
            n_ln = rec.get("probed_candidates", 0)
            # the learned shortlist must time STRICTLY fewer candidates
            if not (0 < n_ln < n_ex):
                print(f"PROBE-COUNT FAILED [{m} {s}]: learned={n_ln} "
                      f"exhaustive={n_ex} (want 0 < learned < exhaustive)",
                      flush=True)
                failures += 1
            # the pick must be within 5% of the exhaustive best, judged
            # on the exhaustive run's own probe table (same measurement,
            # GFLOPs ~ 1/ms so a 1.05x ms bound is the 5%-GFLOPs bound)
            table = _exhaustive_probe_table(m, s, pol)
            label = rec.get("plan_label", "?")
            best = min(table.values()) if table else 0.0
            ratio = (table[label] / best
                     if label in table and best > 0 else float("inf"))
            if ratio > 1.05:
                print(f"PICK-QUALITY FAILED [{m} {s}]: learned pick "
                      f"{label} measured {ratio:.3f}x the exhaustive "
                      f"best (want <= 1.05)", flush=True)
                failures += 1
            print(f"{m},{s},{n_ex},{n_ln},"
                  f"{rec.get('advisor_confidence', 0.0):.3f},"
                  f"{ratio:.3f}", flush=True)

    moved = {k: after.get(k, 0) - before.get(k, 0)
             for k in ("advisor.hits", "advisor.misses",
                       "advisor.fallbacks")}
    print(f"# advisor counters: {moved}", flush=True)
    if moved["advisor.hits"] + moved["advisor.misses"] == 0:
        print("ADVISOR IDLE: no learned cell consulted the knowledge "
              "base (hits+misses == 0)", flush=True)
        failures += 1
    if not any(r.get("advisor_confidence", 0.0) > 0
               for r in rep_ln.records):
        print("ADVISOR UNCONFIDENT: every learned cell fell back to the "
              "model ranking", flush=True)
        failures += 1
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="network-free fixture gate (CI)")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(1 if smoke() else 0)
    derived = run(quick=args.quick)
    print(json.dumps(derived, indent=1))


if __name__ == "__main__":
    main()
