"""Paper Table 1: RCM-vs-METIS win/loss counts under IOS, CG, and YAX.
Claim: IOS and CG agree (RCM wins); YAX flips the conclusion."""
from __future__ import annotations

import numpy as np

from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, grid, write_csv


def run(quick: bool = False):
    mats = suite.locality_names()
    records = common.run_campaign(matrices=mats, schemes=common.SCHEMES,
                                  profiles=(common.PRIMARY,), tag="locality")
    rows, out = [], {}
    for method, field in [("IOS", "seq_ios_gflops"), ("CG", "cg_gflops"),
                          ("YAX", "seq_yax_gflops")]:
        perf = grid(records, common.PRIMARY, mats, common.SCHEMES, field)
        rcm = perf[common.SCHEMES.index("rcm")]
        met = perf[common.SCHEMES.index("metis")]
        ok = np.isfinite(rcm) & np.isfinite(met)
        w = int((rcm[ok] > met[ok]).sum())
        l = int((rcm[ok] < met[ok]).sum())
        rows.append([method, w, l])
        out[f"{method}_rcm_w"] = w
        out[f"{method}_rcm_l"] = l
    write_csv(f"{RESULTS_DIR}/table1_rcm_vs_metis.csv",
              ["method", "rcm_wins", "rcm_losses"], rows)
    out["ios_cg_agree"] = (out["IOS_rcm_w"] > out["IOS_rcm_l"]) == \
        (out["CG_rcm_w"] > out["CG_rcm_l"])
    return out
