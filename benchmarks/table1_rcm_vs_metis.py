"""Paper Table 1: RCM-vs-METIS win/loss counts under IOS, CG, and YAX.
Claim: IOS and CG agree (RCM wins); YAX flips the conclusion.
A pure view over the locality campaign."""
from __future__ import annotations

from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv


def run(quick: bool = False):
    mats = suite.locality_names()
    rep = common.campaign_report(common.locality_spec())
    rows, out = [], {}
    for method, field in [("IOS", "seq_ios_gflops"), ("CG", "cg_gflops"),
                          ("YAX", "seq_yax_gflops")]:
        duel = rep.grid(field, mats, ["rcm", "metis"])
        rcm, met = duel[0], duel[1]
        w = int((rcm > met).sum())
        l = int((rcm < met).sum())
        rows.append([method, w, l])
        out[f"{method}_rcm_w"] = w
        out[f"{method}_rcm_l"] = l
    write_csv(f"{RESULTS_DIR}/table1_rcm_vs_metis.csv",
              ["method", "rcm_wins", "rcm_losses"], rows)
    out["ios_cg_agree"] = (out["IOS_rcm_w"] > out["IOS_rcm_l"]) == \
        (out["CG_rcm_w"] > out["CG_rcm_l"])
    return out
