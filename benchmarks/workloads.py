"""Workload-shaped dynamic sparsity campaigns (`"workload"` cells).

The paper's amortization question asked on model-layer streams: MoE
token routing, block-sparse attention masks, GNN aggregation — each a
per-step sparse structure run through the pipeline under the
WorkloadSession reuse policy (repro.workloads). Two specs because the
scheme axis is constrained by shape: moe dispatch/combine matrices are
rectangular (the dispatch IS the reordering), so they sweep scenarios
under scheme=baseline; attn/gnn matrices are square and sweep
baseline × rcm like everything else.

`run(quick)` is the campaign entry (benchmarks.run MODULES);
`smoke(...)` is the CI gate behind `benchmarks/run.py --smoke-workloads`
(hard-asserts the amortization invariants + resumability);
`moe_dispatch_spec(...)` feeds the byte-compatible moe_dispatch view.
"""
from __future__ import annotations

import json
import os

from repro.experiments import ExperimentSpec, MeasurePolicy, Runner

from .common import RESULTS_DIR, result_store, write_csv

SMOKE_MOE = "workload://moe-e8-k2-t256-d16-n4"
SMOKE_ATTN = "workload://attn-s128-b32-w2-g1-d8-n3"
SMOKE_GNN = "workload://gnn-m256-deg4-f8-n4"

CSV_HEADER = ["workload", "kind", "scenario", "scheme", "steps", "li_mean",
              "drop_frac", "reuse_rate", "plan_cost_share", "plans",
              "replans", "rebuilds", "reuses", "sparse_ms", "ref_ms",
              "speedup_vs_ref", "max_rel_err"]


def _policy(iters: int = 3) -> MeasurePolicy:
    return MeasurePolicy(iters=iters, warmup=0, verify=True,
                         with_yax=False, with_parallel=False,
                         with_metrics=False)


def moe_spec(matrices, name: str = "workloads_moe",
             scenarios=("static", "drift", "shift1"),
             iters: int = 3) -> ExperimentSpec:
    """MoE routing streams: scenarios under scheme=baseline (the sorted
    dispatch is itself the reordering; the rectangular dispatch/combine
    matrices admit no symmetric row/col permutation)."""
    return ExperimentSpec(
        name=name, matrices=tuple(matrices), schemes=("baseline",),
        engines=("auto",), kind="workload", variants=tuple(scenarios),
        policy=_policy(iters))


def structured_spec(matrices, name: str = "workloads_structured",
                    scenarios=("static", "drift", "shift1"),
                    schemes=("baseline", "rcm"),
                    iters: int = 3) -> ExperimentSpec:
    """Square workload streams (attn masks, gnn adjacency): the full
    schemes × scenarios grid — does reordering survive dynamic
    structure once replan cost is on the bill?"""
    return ExperimentSpec(
        name=name, matrices=tuple(matrices), schemes=tuple(schemes),
        engines=("auto",), kind="workload", variants=tuple(scenarios),
        policy=_policy(iters))


def moe_dispatch_spec(tokens: int, steps: int = 2,
                      iters: int = 5) -> ExperimentSpec:
    """The moe_dispatch view's spec: the seed benchmark's (E, k) grid at
    d=128 as drift streams (fresh routing per step — the seed script's
    per-call regime)."""
    mats = tuple(f"workload://moe-e{e}-k{k}-t{tokens}-d128-n{steps}"
                 for e, k in ((16, 2), (64, 8)))
    return moe_spec(mats, name="moe_dispatch", scenarios=("drift",),
                    iters=iters)


def _row(rec) -> list:
    return [rec["matrix"], rec["kind"], rec["variant"] or "drift",
            rec["scheme"], rec["steps"], rec.get("li_mean"),
            rec.get("drop_frac", ""), rec["reuse_rate"],
            rec["plan_cost_share"], rec["plans"], rec["replans"],
            rec["rebuilds"], rec["reuses"], rec.get("sparse_ms"),
            rec.get("ref_ms", ""), rec.get("speedup_vs_ref", ""),
            rec.get("max_rel_err", "")]


def run(quick: bool = False):
    t = 512 if quick else 2048
    specs = [
        moe_spec((f"workload://moe-e8-k2-t{t}-d32-n6",
                  f"workload://moe-e16-k2-t{t}-d128-n4")),
        structured_spec((f"workload://attn-s{256 if quick else 512}"
                         f"-b32-w2-g1-d16-n6",
                         f"workload://gnn-m{512 if quick else 2048}"
                         f"-deg4-f16-n6")),
    ]
    store = result_store()
    records, out = [], {}
    for spec in specs:
        rep = Runner(spec, store=store, verbose=False).run()
        records.extend(rep.records)
    for rec in records:
        scen = rec["variant"] or "drift"
        key = f"{rec['kind']}_{scen}_{rec['scheme']}"
        out[f"{key}_reuse_rate"] = rec["reuse_rate"]
        out[f"{key}_plan_cost_share"] = rec["plan_cost_share"]
        if "speedup_vs_ref" in rec:
            out[f"{key}_speedup"] = rec["speedup_vs_ref"]
    out["verify_ok_all"] = all(r.get("verify_ok", True) for r in records)
    out["static_replans_total"] = sum(
        r["replans"] for r in records if (r["variant"] or "") == "static")
    write_csv(os.path.join(RESULTS_DIR, "workloads.csv"), CSV_HEADER,
              [_row(r) for r in records])
    return out


def smoke(matrices=None) -> int:
    """CI gate: MoE + block-attention + GNN streams through the
    ResultStore with the amortization invariants hard-asserted —
    value-only streams never replan (and moe stays bitwise-equal to the
    onehot oracle), a single mid-stream structure change replans the
    gnn stream exactly once, and the identical re-run is served 100%
    from the store. Returns failure count."""
    mats = tuple(matrices or (SMOKE_MOE, SMOKE_ATTN, SMOKE_GNN))
    moe_mats = tuple(m for m in mats if m.startswith("workload://moe"))
    sq_mats = tuple(m for m in mats if m not in moe_mats)
    specs = []
    if moe_mats:
        specs.append(moe_spec(moe_mats, name="smoke_workloads_moe"))
    if sq_mats:
        specs.append(structured_spec(sq_mats, name="smoke_workloads_sq",
                                     schemes=("baseline", "rcm")))
    store = result_store()
    failures, records, n_cells = 0, [], 0
    print("name,us_per_call,derived")
    for spec in specs:
        rep = Runner(spec, store=store, verbose=False,
                     on_error="record").run()
        failures += len(rep.failures)
        for f in rep.failures:
            print(f"{f['label']},0,\"ERROR: {f['error']}\"", flush=True)
            print(f["traceback"], flush=True)
        records.extend(rep.records)
        n_cells += len(spec.cells())
    for rec in records:
        scen = rec["variant"] or "drift"
        derived = {"scenario": scen, "scheme": rec["scheme"],
                   "reuse_rate": rec["reuse_rate"],
                   "plan_share": rec["plan_cost_share"],
                   "replans": rec["replans"], "li": rec.get("li_mean"),
                   "speedup": rec.get("speedup_vs_ref"),
                   "store": "hit" if rec["store_reused"] else "miss+measure"}
        print(f"{rec['matrix']}_{scen}_{rec['scheme']},"
              f"{rec['runner_wall_s'] * 1e6:.0f},"
              f"\"{json.dumps(derived)}\"", flush=True)
        bad = []
        # every cell is oracle-gated (onehot scatter for moe, dense
        # matmul for attn/gnn)
        if not rec.get("verify_ok", False):
            bad.append(f"verify failed (max_rel_err="
                       f"{rec.get('max_rel_err')})")
        if rec["kind"] == "moe":
            if not rec.get("dispatch_bitwise_equal", False):
                bad.append("dispatch buffer NOT bitwise-equal to the "
                           "onehot oracle")
            if not rec.get("dispatch_agree", False):
                bad.append("sorted-vs-onehot combine disagree (>=1e-3)")
        # the amortization invariants:
        if scen == "static" and rec["replans"] != 0:
            bad.append(f"value-only stream replanned "
                       f"{rec['replans']} times (want 0)")
        if scen == "static" and rec["reuse_rate"] <= 0:
            bad.append("value-only stream shows zero reuse")
        if rec["kind"] == "gnn" and scen == "shift1" \
                and rec["replans"] != 1:
            bad.append(f"one structure change replanned "
                       f"{rec['replans']} times (want exactly 1)")
        if bad:
            failures += 1
            print(f"WORKLOAD INVARIANT FAILED "
                  f"[{rec['matrix']} {scen} {rec['scheme']}]: "
                  f"{'; '.join(bad)}", flush=True)

    if not failures:
        reused = measured = 0
        for spec in specs:
            rep2 = Runner(spec, store=store, verbose=False).run()
            reused += rep2.reused
            measured += rep2.measured
        if measured != 0 or reused != n_cells:
            print(f"RESUME FAILED: second run measured={measured} "
                  f"reused={reused} (want 0/{n_cells})", flush=True)
            failures += 1
        else:
            print(f"# resume: {reused}/{n_cells} cells served from the "
                  f"store (0 re-measured)", flush=True)

    write_csv(os.path.join(RESULTS_DIR, "smoke_workloads_campaign.csv"),
              CSV_HEADER, [_row(r) for r in records])
    return failures
