"""Roofline table (spec deliverable g) from the dry-run records.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs / peak_FLOP/s            (per-chip values)
  memory term     = HLO_bytes / HBM_bw
  collective term = collective_bytes / ICI link bw
  dominant bottleneck, MODEL_FLOPS = 6*N(_active)*D, useful ratio.

HLO_FLOPs/bytes come from the scan-aware walker (launch/hlo_cost) — XLA's
cost_analysis counts while bodies once. Values are per device, so no /chips.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs.base import SHAPES
from repro.launch.mesh import HardwareSpec

from .common import RESULTS_DIR, write_csv

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def model_flops_per_device(rec) -> float:
    """6 * N(_active) * tokens / chips (train includes backward: the 6x;
    decode/prefill use 2*N*D forward-only)."""
    shape = SHAPES[rec["shape"]]
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    n = rec.get("active_params") or rec.get("params")
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
        # our train step microbatches but still one optimizer update
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / chips


def load_records():
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = False):
    hw = HardwareSpec
    rows = []
    summary = {"cells_ok": 0, "cells_err": 0}
    for rec in load_records():
        if rec.get("status") != "ok":
            summary["cells_err"] += 1
            rows.append([rec["arch"], rec["shape"], rec["mesh"], "ERROR",
                         "", "", "", "", "", "", rec.get("error", "")[:80]])
            continue
        summary["cells_ok"] += 1
        flops = rec.get("walk_flops", 0.0)
        bytes_ = rec.get("walk_bytes", 0.0)
        coll = rec.get("collectives", {})
        wire = coll.get("wire", coll.get("total", 0))
        t_compute = flops / hw["peak_flops_bf16"]
        t_memory = bytes_ / hw["hbm_bw"]
        t_coll = wire / hw["ici_bw"]
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        dominant = max(terms, key=terms.get)
        step_time = max(terms.values())
        mf = model_flops_per_device(rec)
        useful = mf / max(flops, 1.0)
        # roofline fraction: useful model flops per second vs peak
        mfu_bound = mf / max(step_time, 1e-12) / hw["peak_flops_bf16"]
        rows.append([
            rec["arch"], rec["shape"], rec["mesh"], "ok",
            f"{t_compute:.4e}", f"{t_memory:.4e}", f"{t_coll:.4e}",
            dominant, f"{useful:.3f}", f"{mfu_bound:.3f}", "",
        ])
    write_csv(os.path.join(RESULTS_DIR, "roofline.csv"),
              ["arch", "shape", "mesh", "status", "compute_s", "memory_s",
               "collective_s", "dominant", "model_over_hlo_flops",
               "roofline_fraction", "note"], rows)
    return summary
