"""Paper Fig. 5: Dolan-More performance profiles of the reordering schemes,
sequential (measured) and parallel (modelled) — IOS methodology. A pure
view over the locality campaign."""
from __future__ import annotations

import numpy as np

from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv

TAUS = np.array([1.0, 1.05, 1.1, 1.25, 1.5, 2.0, 3.0])


def run(quick: bool = False):
    mats = suite.locality_names()
    rep = common.campaign_report(common.locality_spec())
    schemes = common.SCHEMES
    out = {}
    rows = []
    for mode, field in [("sequential", "seq_ios_gflops"),
                        ("parallel_modelled", "par_static_gflops")]:
        prof = rep.performance_profile(field, mats, schemes, TAUS)
        for i, s in enumerate(schemes):
            for t, v in zip(TAUS, prof[i]):
                rows.append([mode, s, float(t), round(float(v), 4)])
        # winner at tau=1 (fraction of matrices where scheme is the best)
        out[f"{mode}_tau1"] = {s: round(float(prof[i, 0]), 3)
                               for i, s in enumerate(schemes)}
    write_csv(f"{RESULTS_DIR}/fig05_profiles.csv",
              ["mode", "scheme", "tau", "fraction"], rows)
    return out
