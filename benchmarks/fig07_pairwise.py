"""Paper Fig. 7: pairwise win-rate matrix across schemes (IOS GFLOPs).
Claim: RCM beats every other scheme on most matrices. A pure view over
the locality campaign."""
from __future__ import annotations

from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv


def run(quick: bool = False):
    mats = suite.locality_names()
    rep = common.campaign_report(common.locality_spec())
    schemes = common.SCHEMES
    out, rows = {}, []
    for mode, field in [("sequential", "seq_ios_gflops"),
                        ("parallel_modelled", "par_static_gflops")]:
        win = rep.pairwise_win_rates(field, mats, schemes)
        for i, si in enumerate(schemes):
            for j, sj in enumerate(schemes):
                rows.append([mode, si, sj, round(float(win[i, j]), 3)])
        r = schemes.index("rcm")
        out[f"{mode}_rcm_beats_all"] = bool(
            all(win[r, j] >= 0.5 for j in range(len(schemes)) if j != r))
        out[f"{mode}_rcm_vs_metis"] = round(
            float(win[r, schemes.index("metis")]), 3)
    write_csv(f"{RESULTS_DIR}/fig07_pairwise.csv",
              ["mode", "row_scheme", "col_scheme", "win_rate"], rows)
    return out
