"""Benchmark orchestrator — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only figXX,...]
  PYTHONPATH=src python -m benchmarks.run --smoke   # CI: tiny end-to-end pass

--smoke runs a tiny measurement CAMPAIGN (smoke-tier matrices x
{baseline, rcm} with the autotuned engine, interpret-mode kernels on CPU)
through the experiment harness: reorder -> tune -> build -> operator
store -> IOS timing with a per-cell original-index-space oracle gate.
It then re-runs the identical spec and asserts 100% result-store hits
(the resumability invariant), writes the campaign CSV, and emits the
top-level BENCH_spmv.json trajectory summary. Exit status is nonzero on
any failure. --matrices restricts the smoke grid (CI's 2-matrix x
2-scheme job)."""
from __future__ import annotations

import argparse
import importlib
import json
import os
import time
import traceback

MODULES = [
    "fig01_banded_shuffle",
    "fig03_ios_yax",
    "fig04_scheduling",
    "fig05_profiles",
    "fig06_speedup_stacks",
    "fig07_pairwise",
    "fig08_consistency",
    "fig09_10_load_imbalance",
    "fig11_nnz_balanced",
    "table1_rcm_vs_metis",
    "bell_formats",
    "moe_dispatch",
    "roofline",
    "spmm_batch",
    "corpus_scale",
    "workloads",
]

BENCH_SUMMARY_PATH = os.path.join(os.path.dirname(__file__), "..",
                                  "BENCH_spmv.json")


def smoke_spec(matrices=None):
    from repro.experiments import ExperimentSpec, MeasurePolicy
    from repro.matrices import suite

    return ExperimentSpec(
        name="smoke", matrices=tuple(matrices or suite.smoke_names()),
        schemes=("baseline", "rcm"), engines=("auto",),
        # interpret-mode keeps the Pallas kernel path covered on CPU
        # whenever the tuner picks a kernel engine; verify gates every
        # cell on the numpy oracle in the ORIGINAL index space (this also
        # exercises the operator's carried permutation); probe exercises
        # the empirical tuner path so a traced smoke run carries the full
        # plan -> probe -> build -> kernel span nest
        policy=MeasurePolicy(iters=3, warmup=1, with_yax=False,
                             with_parallel=False, with_metrics=False,
                             verify=True, probe=True,
                             use_kernel="interpret"))


def smoke(matrices=None) -> int:
    """Tiny end-to-end campaign + resumability check for CI.
    Returns failure count."""
    from . import common

    spec = smoke_spec(matrices)
    store = common.result_store()
    rep = common.Runner(spec, store=store, verbose=False,
                        on_error="record").run()
    print("name,us_per_call,derived")
    for rec in rep.records:
        derived = {"engine": rec.get("engine", "?"),
                   "ms": round(rec.get("seq_ios_ms", float("nan")), 3),
                   "store": "hit" if rec["store_reused"] else "miss+measure",
                   "verify_rel_err": round(rec.get("verify_rel_err", -1.0),
                                           8)}
        print(f"{rec['matrix']}_{rec['scheme']},"
              f"{rec['runner_wall_s'] * 1e6:.0f},"
              f"\"{json.dumps(derived)}\"", flush=True)
    failures = len(rep.failures)
    for f in rep.failures:
        print(f"{f['label']},0,\"ERROR: {f['error']}\"", flush=True)
        print(f["traceback"], flush=True)

    if not failures:
        # the resumability invariant: an identical second invocation is
        # served ENTIRELY from the result store
        rep2 = common.Runner(spec, store=store, verbose=False).run()
        if rep2.measured != 0 or rep2.reused != len(spec.cells()):
            print(f"RESUME FAILED: second run measured={rep2.measured} "
                  f"reused={rep2.reused} (want 0/{len(spec.cells())})",
                  flush=True)
            failures += 1
        else:
            print(f"# resume: {rep2.reused}/{len(spec.cells())} cells "
                  f"served from the store (0 re-measured)", flush=True)
        rep = rep2 if not failures else rep

    # campaign CSV + the top-level trajectory summary
    rows = [[r["matrix"], r["scheme"], r.get("engine", "?"),
             r.get("plan_label", "?"), round(r.get("seq_ios_ms", -1), 4),
             round(r.get("seq_ios_gflops", -1), 4),
             round(r.get("verify_rel_err", -1), 8)] for r in rep.records]
    common.write_csv(os.path.join(common.RESULTS_DIR, "smoke_campaign.csv"),
                     ["matrix", "scheme", "engine", "plan_label",
                      "seq_ios_ms", "seq_ios_gflops", "verify_rel_err"],
                     rows)
    summary = rep.write_bench_summary(os.path.abspath(BENCH_SUMMARY_PATH))
    print(f"# BENCH_spmv.json: geomean={summary['geomean']} "
          f"speedup={summary.get('speedup_vs_baseline', {})}", flush=True)
    return failures


def smoke_parallel_spec(matrices=None, devices: int = 8):
    from repro.experiments import ExperimentSpec, MeasurePolicy
    from repro.experiments.cells import parallel_variant

    if devices < 2:
        raise SystemExit(f"--smoke-parallel needs --devices >= 2, "
                         f"got {devices}")
    return ExperimentSpec(
        name="smoke_parallel",
        matrices=tuple(matrices or ("smoke_banded", "smoke_powerlaw")),
        schemes=("baseline", "rcm"), engines=("auto",), ps=(devices,),
        kind="parallel",
        variants=(parallel_variant("1d_rows", "nnz_balanced"),
                  parallel_variant("2d_panels", "nnz_balanced")),
        # verify gates every cell on the ShardedOperator's original-
        # index-space oracle; on an 8-device host (XLA_FLAGS in CI) this
        # exercises the real shard_map collectives, not the simulation
        policy=MeasurePolicy(iters=3, warmup=1, verify=True,
                             with_yax=False, with_parallel=False,
                             with_metrics=False))


def smoke_parallel(matrices=None, devices: int = 8) -> int:
    """Distributed-smoke campaign + resumability check for CI.
    Returns failure count."""
    from . import common

    spec = smoke_parallel_spec(matrices, devices)
    store = common.result_store()
    rep = common.Runner(spec, store=store, verbose=False,
                        on_error="record").run()
    print("name,us_per_call,derived")
    for rec in rep.records:
        derived = {"layout": rec["layout"], "engine": rec.get("engine", "?"),
                   "sched": rec.get("comm_schedule", "?"),
                   "comm_B": rec.get("comm_bytes_per_spmv"),
                   "par_ms": round(rec.get("modelled_par_ms",
                                           float("nan")), 3),
                   "sim": rec.get("simulated"),
                   "store": "hit" if rec["store_reused"] else "miss+measure",
                   "verify_rel_err": round(rec.get("verify_rel_err", -1.0),
                                           8)}
        print(f"{rec['matrix']}_{rec['scheme']}_{rec['layout']}"
              f"_{rec['partitioner']},"
              f"{rec['runner_wall_s'] * 1e6:.0f},"
              f"\"{json.dumps(derived)}\"", flush=True)
    failures = len(rep.failures)
    for f in rep.failures:
        print(f"{f['label']},0,\"ERROR: {f['error']}\"", flush=True)
        print(f["traceback"], flush=True)

    if not failures:
        # resumability: an identical second invocation is served ENTIRELY
        # from the result store (the sharded plan store makes even a
        # --fresh re-measure reload its operators, but this asserts the
        # stronger cell-level invariant)
        rep2 = common.Runner(spec, store=store, verbose=False).run()
        if rep2.measured != 0 or rep2.reused != len(spec.cells()):
            print(f"RESUME FAILED: second run measured={rep2.measured} "
                  f"reused={rep2.reused} (want 0/{len(spec.cells())})",
                  flush=True)
            failures += 1
        else:
            print(f"# resume: {rep2.reused}/{len(spec.cells())} cells "
                  f"served from the store (0 re-measured)", flush=True)
        rep = rep2 if not failures else rep

    rows = [[r["matrix"], r["scheme"], r["layout"], r["partitioner"],
             r.get("engine", "?"), r.get("comm_schedule", "?"),
             r.get("comm_bytes_per_spmv", -1),
             round(r.get("li", -1.0), 4),
             round(r.get("modelled_par_ms", -1.0), 4),
             round(r.get("verify_rel_err", -1.0), 8)]
            for r in rep.records]
    common.write_csv(os.path.join(common.RESULTS_DIR,
                                  "smoke_parallel_campaign.csv"),
                     ["matrix", "scheme", "layout", "partitioner", "engine",
                      "comm_schedule", "comm_bytes_per_spmv", "li",
                      "modelled_par_ms", "verify_rel_err"],
                     rows)
    return failures


def smoke_serve_spec(matrices=None):
    from repro.experiments import ExperimentSpec, MeasurePolicy
    from repro.experiments.cells import serve_variant

    # three overload scenarios, all rate >> capacity with Zipf-skewed
    # keys and an operator footprint past the memory budget (the ISSUE 6
    # soak shape): one per shedding policy, the degrade one with a
    # value-update mix on bursty arrivals
    variants = (
        serve_variant(rate_rps=4000, requests=160, n_keys=5, zipf_s=1.1,
                      budget_mb=0.02, max_queue=8, window_ms=1.0,
                      overload="reject"),
        serve_variant(rate_rps=4000, requests=160, n_keys=5, zipf_s=1.1,
                      budget_mb=0.02, max_queue=8, window_ms=1.0,
                      overload="shed-oldest"),
        serve_variant(arrival="bursty", rate_rps=2000, requests=120,
                      n_keys=3, update_frac=0.25, budget_mb=0.02,
                      max_queue=16, window_ms=1.0,
                      overload="degrade-to-k1"),
    )
    return ExperimentSpec(
        name="smoke_serve", matrices=tuple(matrices or ("smoke_banded",)),
        schemes=("baseline",), engines=("auto",), ks=(8,), kind="serve",
        variants=variants,
        policy=MeasurePolicy(iters=1, warmup=0, with_yax=False,
                             with_parallel=False, with_metrics=False,
                             use_kernel="interpret"))


SERVE_SLO_PATH = os.path.join(os.path.dirname(__file__), "results",
                              "serve_slo.json")


def smoke_serve(matrices=None) -> int:
    """Traffic-sim soak campaign for CI: three overload scenarios through
    the 'serve' cell kind, hard-asserting the hardening invariants —
    every future resolves, resident bytes never exceed the budget,
    counters balance, overload sheds only via typed retryable errors,
    the LRU evicts and reloads, and the update mix value-swaps without
    replanning. Writes the SLO summary JSON (the CI artifact) and checks
    result-store resumability. Returns failure count."""
    from . import common

    spec = smoke_serve_spec(matrices)
    store = common.result_store()
    rep = common.Runner(spec, store=store, verbose=False,
                        on_error="record").run()
    print("name,us_per_call,derived")
    failures = len(rep.failures)
    for f in rep.failures:
        print(f"{f['label']},0,\"ERROR: {f['error']}\"", flush=True)
        print(f["traceback"], flush=True)
    for rec in rep.records:
        derived = {"variant": rec["variant"],
                   "ok": rec["ok"], "shed": rec["shed"],
                   "rejected": rec["rejected"], "errors": rec["errors"],
                   "unresolved": rec["unresolved"],
                   "p99_ms": round(rec["p99_ms"], 2),
                   "coalesce": round(rec["coalesce_ratio"], 2),
                   "evictions": rec["evictions"],
                   "reloads": rec["op_reloads"],
                   "swaps": rec["value_swaps"],
                   "store": "hit" if rec["store_reused"] else "miss+measure"}
        print(f"{rec['matrix']}_{rec['variant']},"
              f"{rec['runner_wall_s'] * 1e6:.0f},"
              f"\"{json.dumps(derived)}\"", flush=True)
        # per-cell hard invariants (the acceptance criteria):
        bad = []
        if rec["unresolved"]:
            bad.append(f"unresolved={rec['unresolved']} futures")
        if not rec["budget_ok"]:
            bad.append(f"resident_bytes_max={rec['resident_bytes_max']} "
                       f"exceeded budget={rec['memory_budget_bytes']}")
        if not rec["counters_balanced"]:
            bad.append("stats counters do not balance")
        if rec["errors"]:
            bad.append(f"{rec['errors']} non-typed request errors")
        if (rec["rejected"] or rec["shed"]) \
                and not rec["retry_after_positive"]:
            bad.append("overload error without positive retry_after_ms")
        if bad:
            failures += 1
            print(f"SOAK INVARIANT FAILED [{rec['variant']}]: "
                  f"{'; '.join(bad)}", flush=True)
    if rep.records and not failures:
        # campaign-level: the overload scenarios must actually overload
        # (shed/reject), thrash the LRU (evict + reload zero-re-tune)
        # and value-swap without replanning
        tot = {k: sum(r[k] for r in rep.records)
               for k in ("shed", "rejected", "evictions", "op_reloads",
                         "value_swaps", "updates", "replans")}
        if tot["shed"] + tot["rejected"] == 0:
            failures += 1
            print("SOAK UNDERLOADED: no request was shed or rejected — "
                  "the scenarios no longer exceed capacity", flush=True)
        if tot["evictions"] == 0 or tot["op_reloads"] == 0:
            failures += 1
            print(f"SOAK LRU NOT EXERCISED: evictions={tot['evictions']} "
                  f"plan-store reloads={tot['op_reloads']}", flush=True)
        if tot["updates"] and (tot["value_swaps"] == 0 or tot["replans"]):
            failures += 1
            print(f"SOAK VALUE-SWAP FAILED: updates={tot['updates']} "
                  f"swaps={tot['value_swaps']} replans={tot['replans']} "
                  f"(updates must swap values without replanning)",
                  flush=True)

    if not failures:
        # resumability: the identical spec re-runs entirely from the store
        rep2 = common.Runner(spec, store=store, verbose=False).run()
        if rep2.measured != 0 or rep2.reused != len(spec.cells()):
            print(f"RESUME FAILED: second run measured={rep2.measured} "
                  f"reused={rep2.reused} (want 0/{len(spec.cells())})",
                  flush=True)
            failures += 1
        else:
            print(f"# resume: {rep2.reused}/{len(spec.cells())} cells "
                  f"served from the store (0 re-measured)", flush=True)

    rows = [[r["matrix"], r["variant"], r["ok"], r["shed"], r["rejected"],
             r["errors"], r["unresolved"],
             round(r["p50_ms"], 3), round(r["p99_ms"], 3),
             round(r["coalesce_ratio"], 3), r["evictions"],
             r["op_reloads"], r["value_swaps"], r["resident_bytes_max"]]
            for r in rep.records]
    common.write_csv(os.path.join(common.RESULTS_DIR,
                                  "smoke_serve_campaign.csv"),
                     ["matrix", "variant", "ok", "shed", "rejected",
                      "errors", "unresolved", "p50_ms", "p99_ms",
                      "coalesce_ratio", "evictions", "op_reloads",
                      "value_swaps", "resident_bytes_max"],
                     rows)
    summary = {"failures": failures, "cells": len(spec.cells()),
               "records": rep.records}
    os.makedirs(os.path.dirname(SERVE_SLO_PATH), exist_ok=True)
    with open(SERVE_SLO_PATH, "w") as f:
        json.dump(summary, f, indent=1, default=str)
    print(f"# serve SLO summary -> {os.path.relpath(SERVE_SLO_PATH)}",
          flush=True)
    return failures


def smoke_route_spec(matrices=None, devices: int = 8):
    from repro.experiments import ExperimentSpec, MeasurePolicy
    from repro.experiments.cells import route_variant

    d = max(2, min(4, devices // 2))
    # two fleet scenarios: budgeted bin-pack with a structure-delta +
    # value-swap mix (the mid-soak shard-replan shape), and a
    # comm-model-aware placement over wider meshes
    variants = (
        route_variant(rate_rps=600, requests=120, n_keys=4,
                      update_frac=0.1, structure_frac=0.08,
                      devices=d, meshes=2, policy="bin_pack",
                      budget_mb=4.0, window_ms=1.0),
        route_variant(rate_rps=600, requests=80, n_keys=3,
                      structure_frac=0.05, devices=d, meshes=2,
                      policy="comm_aware", window_ms=1.0),
    )
    return ExperimentSpec(
        name="smoke_route", matrices=tuple(matrices or ("smoke_banded",)),
        schemes=("baseline",), engines=("auto",), ks=(4,), kind="route",
        variants=variants,
        policy=MeasurePolicy(iters=1, warmup=0, with_yax=False,
                             with_parallel=False, with_metrics=False,
                             use_kernel="interpret"))


ROUTE_SUMMARY_PATH = os.path.join(os.path.dirname(__file__), "results",
                                  "route_smoke.json")


def _route_delta_vs_replan() -> int:
    """Hard-assert `Plan.apply_delta` is measurably cheaper than a full
    replan of the edited matrix, pinned by the delta.applies counter.
    Returns failure count."""
    import numpy as np

    from repro import obs
    from repro.api import SpmvProblem, plan
    from repro.core.spmv.delta import StructureDelta
    from repro.matrices import generators as G

    mat = G.banded(4096, 24, seed=0)
    pl = plan(SpmvProblem(mat), reorder="rcm", cache=False)
    rows = np.repeat(np.arange(mat.shape[0], dtype=np.int64),
                     np.diff(mat.rowptr.astype(np.int64)))
    pick = np.arange(0, mat.nnz, max(mat.nnz // 64, 1))[:64]
    delta = StructureDelta(del_rows=rows[pick],
                           del_cols=mat.cols.astype(np.int64)[pick])
    applies0 = obs.counter("delta.applies").value
    t0 = time.perf_counter()
    pl2 = pl.apply_delta(delta)
    delta_ms = (time.perf_counter() - t0) * 1e3
    applies1 = obs.counter("delta.applies").value
    new_mat = delta.apply_to(mat)
    t0 = time.perf_counter()
    pl3 = plan(SpmvProblem(new_mat), reorder="rcm", cache=False)
    replan_ms = (time.perf_counter() - t0) * 1e3
    fails = 0
    if applies1 != applies0 + 1:
        fails += 1
        print(f"DELTA COUNTER FAILED: delta.applies moved "
              f"{applies1 - applies0}, want 1", flush=True)
    if pl2.key == pl.key or tuple(pl2.mat_shape) != tuple(new_mat.shape) \
            or pl2.mat_nnz != new_mat.nnz:
        fails += 1
        print("DELTA PLAN FAILED: apply_delta did not re-key the plan "
              "onto the edited structure", flush=True)
    if delta_ms >= replan_ms:
        fails += 1
        print(f"DELTA NOT CHEAPER: apply_delta {delta_ms:.2f} ms >= "
              f"full replan {replan_ms:.2f} ms", flush=True)
    print(f"# delta-vs-replan: apply_delta {delta_ms:.2f} ms vs "
          f"plan() {replan_ms:.2f} ms ({replan_ms / max(delta_ms, 1e-9):.1f}x"
          f"); replanned scheme={pl3.scheme}", flush=True)
    return fails


def _route_sibling_p99_flat(devices: int) -> int:
    """Soak one mesh with two keys; trigger a background shard replan on
    one and hard-assert the SIBLING key's p99 stays flat (the
    non-stalling replan pillar). Returns failure count."""
    import numpy as np

    from repro.core.spmv.topology import Topology
    from repro.matrices import generators as G
    from repro.router import MeshSpec, RoutedSpmvService
    from repro.serving.traffic import _deletion_delta

    def p99(samples):
        s = sorted(samples)
        return s[min(len(s) - 1, int(0.99 * len(s)))]

    mesh = MeshSpec("m0", Topology(devices=max(2, min(4, devices // 2))))
    sib_mat = G.banded(1024, 16, seed=1)
    hot_mat = G.banded(2048, 32, seed=2)
    rng = np.random.default_rng(3)
    x = rng.standard_normal(sib_mat.shape[1])

    def lat_run(svc, n):
        out = []
        for _ in range(n):
            t0 = time.perf_counter()
            svc.submit("sib", x).result(timeout=60)
            out.append((time.perf_counter() - t0) * 1e3)
        return out

    fails = 0
    with RoutedSpmvService([mesh], max_batch=4, window_ms=0.5,
                           use_kernel="interpret") as rt:
        rt.register("sib", sib_mat, mesh="m0")
        rt.register("hot", hot_mat, mesh="m0")
        rt.operator("sib")
        rt.operator("hot")
        base = lat_run(rt, 40)
        fut = rt.update_structure(
            "hot", delta=_deletion_delta(hot_mat, rng, frac=0.01))
        during = lat_run(rt, 40)          # sibling serves while replanning
        fut.result(timeout=120)
        st = rt.stats()
        if st["replans"] != 1 or st["replan_errors"]:
            fails += 1
            print(f"SIBLING REPLAN FAILED: replans={st['replans']} "
                  f"errors={st['replan_errors']} (want exactly 1 clean "
                  f"background replan)", flush=True)
        p_base, p_during = p99(base), p99(during)
        # generous noise envelope for CI: the non-stalling property fails
        # CATASTROPHICALLY when broken (sibling gates on the replan), so
        # 5x + 50 ms separates broken from noisy cleanly
        if p_during > 5.0 * p_base + 50.0:
            fails += 1
            print(f"SIBLING P99 NOT FLAT: {p_during:.2f} ms during replan "
                  f"vs {p_base:.2f} ms baseline", flush=True)
        print(f"# sibling p99: {p_base:.2f} ms baseline -> "
              f"{p_during:.2f} ms during background replan", flush=True)
    return fails


def smoke_route(matrices=None, devices: int = 8) -> int:
    """Multi-shard router soak for CI: routed-fleet traffic through the
    'route' cell kind, hard-asserting the router invariants — every
    future (requests AND replans) resolves, counters balance, no device
    exceeds its per-device budget, the mid-soak shard replan leaves the
    sibling key's p99 flat, and delta-apply is measurably cheaper than a
    full replan. Writes the route summary JSON (the CI artifact) and
    checks result-store resumability. Returns failure count."""
    from . import common

    spec = smoke_route_spec(matrices, devices)
    store = common.result_store()
    rep = common.Runner(spec, store=store, verbose=False,
                        on_error="record").run()
    print("name,us_per_call,derived")
    failures = len(rep.failures)
    for f in rep.failures:
        print(f"{f['label']},0,\"ERROR: {f['error']}\"", flush=True)
        print(f["traceback"], flush=True)
    for rec in rep.records:
        derived = {"variant": rec["variant"], "ok": rec["ok"],
                   "unresolved": rec["unresolved"],
                   "replans_landed": rec["replans_landed"],
                   "replan_unresolved": rec["replan_unresolved"],
                   "per_device_ok": rec["per_device_ok"],
                   "placement": rec["placement"],
                   "assignments": rec["assignments"],
                   "store": "hit" if rec["store_reused"] else "miss+measure"}
        print(f"{rec['matrix']}_{rec['variant']},"
              f"{rec['runner_wall_s'] * 1e6:.0f},"
              f"\"{json.dumps(derived)}\"", flush=True)
        bad = []
        if rec["unresolved"] or rec["replan_unresolved"]:
            bad.append(f"unresolved futures: requests="
                       f"{rec['unresolved']} replans="
                       f"{rec['replan_unresolved']}")
        if rec["errors"] or rec["replan_errors"]:
            bad.append(f"errors: requests={rec['errors']} "
                       f"replans={rec['replan_errors']}")
        if not rec["per_device_ok"] or not rec["budget_ok"]:
            bad.append(f"per-device budget violated (per_device_ok="
                       f"{rec['per_device_ok']} budget_ok="
                       f"{rec['budget_ok']})")
        if not rec["counters_balanced"]:
            bad.append("stats counters do not balance")
        if rec["structure_updates"] \
                and rec["replans_landed"] != rec["structure_updates"]:
            bad.append(f"{rec['structure_updates']} structure updates but "
                       f"{rec['replans_landed']} replans landed")
        if rec["placement"] != "bin_pack" \
                and len(set(rec["assignments"].values())) < 2:
            # bin_pack is best-fit and legitimately packs one mesh; the
            # load-spreading policies must actually spread
            bad.append(f"placement degenerate: all keys on one mesh "
                       f"({rec['assignments']})")
        if bad:
            failures += 1
            print(f"ROUTE INVARIANT FAILED [{rec['variant']}]: "
                  f"{'; '.join(bad)}", flush=True)

    if not failures:
        failures += _route_sibling_p99_flat(devices)
        failures += _route_delta_vs_replan()

    if not failures:
        # resumability: the identical spec re-runs entirely from the store
        rep2 = common.Runner(spec, store=store, verbose=False).run()
        if rep2.measured != 0 or rep2.reused != len(spec.cells()):
            print(f"RESUME FAILED: second run measured={rep2.measured} "
                  f"reused={rep2.reused} (want 0/{len(spec.cells())})",
                  flush=True)
            failures += 1
        else:
            print(f"# resume: {rep2.reused}/{len(spec.cells())} cells "
                  f"served from the store (0 re-measured)", flush=True)

    rows = [[r["matrix"], r["variant"], r["placement"], r["ok"],
             r["unresolved"], r["structure_updates"], r["replans_landed"],
             r["value_swaps"], int(r["per_device_ok"]),
             json.dumps(r["assignments"])]
            for r in rep.records]
    common.write_csv(os.path.join(common.RESULTS_DIR,
                                  "smoke_route_campaign.csv"),
                     ["matrix", "variant", "placement", "ok", "unresolved",
                      "structure_updates", "replans_landed", "value_swaps",
                      "per_device_ok", "assignments"],
                     rows)
    summary = {"failures": failures, "cells": len(spec.cells()),
               "records": rep.records}
    os.makedirs(os.path.dirname(ROUTE_SUMMARY_PATH), exist_ok=True)
    with open(ROUTE_SUMMARY_PATH, "w") as f:
        json.dump(summary, f, indent=1, default=str)
    print(f"# route summary -> {os.path.relpath(ROUTE_SUMMARY_PATH)}",
          flush=True)
    return failures


def main() -> None:
    import contextlib

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--smoke-parallel", action="store_true",
                    help="distributed-smoke campaign over the 'parallel' "
                         "cell kind (topology-aware plans)")
    ap.add_argument("--smoke-serve", action="store_true",
                    help="traffic-sim soak campaign over the 'serve' cell "
                         "kind (hardened-service invariants)")
    ap.add_argument("--smoke-route", action="store_true",
                    help="multi-shard router soak over the 'route' cell "
                         "kind (placement, per-device budgets, delta "
                         "shard replans)")
    ap.add_argument("--smoke-workloads", action="store_true",
                    help="dynamic-sparsity campaign over the 'workload' "
                         "cell kind (moe/attn/gnn streams + amortization "
                         "invariants)")
    ap.add_argument("--devices", type=int, default=8,
                    help="device count for --smoke-parallel/--smoke-route")
    ap.add_argument("--matrices", default="",
                    help="comma-separated matrix names (restricts --smoke)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="record phase-attributed spans for the whole run: "
                         ".jsonl -> raw event log, anything else -> "
                         "Chrome-trace JSON (load in ui.perfetto.dev)")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    @contextlib.contextmanager
    def traced():
        if not args.trace:
            yield
            return
        from repro import obs

        with obs.tracing() as buf:
            yield
        obs.write_trace(args.trace, buf.flush())
        print(f"# trace: {len(buf)} span events -> {args.trace}",
              flush=True)

    if args.smoke_parallel:
        mats = [m for m in args.matrices.split(",") if m] or None
        with traced():
            rc = 1 if smoke_parallel(mats, args.devices) else 0
        raise SystemExit(rc)
    if args.smoke_serve:
        mats = [m for m in args.matrices.split(",") if m] or None
        with traced():
            rc = 1 if smoke_serve(mats) else 0
        raise SystemExit(rc)
    if args.smoke_route:
        mats = [m for m in args.matrices.split(",") if m] or None
        with traced():
            rc = 1 if smoke_route(mats, args.devices) else 0
        raise SystemExit(rc)
    if args.smoke_workloads:
        from . import workloads as workloads_mod

        mats = [m for m in args.matrices.split(",") if m] or None
        with traced():
            rc = 1 if workloads_mod.smoke(mats) else 0
        raise SystemExit(rc)
    if args.smoke:
        mats = [m for m in args.matrices.split(",") if m] or None
        with traced():
            rc = 1 if smoke(mats) else 0
        raise SystemExit(rc)
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            derived = mod.run(quick=args.quick)
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},\"{json.dumps(derived, default=str)}\"",
                  flush=True)
        except Exception as e:
            failures += 1
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},\"ERROR: {type(e).__name__}: {e}\"",
                  flush=True)
            traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
