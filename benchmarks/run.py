"""Benchmark orchestrator — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only figXX,...]
  PYTHONPATH=src python -m benchmarks.run --smoke   # CI: tiny end-to-end pass

--smoke runs a minimal measurement pass on the smoke-tier matrices with the
autotuned engine (interpret-mode kernels on CPU), exercising reorder ->
tune -> build -> operator cache -> IOS timing without the full campaign
cost. Exit status is nonzero on any failure."""
from __future__ import annotations

import argparse
import importlib
import json
import time
import traceback

MODULES = [
    "fig01_banded_shuffle",
    "fig03_ios_yax",
    "fig04_scheduling",
    "fig05_profiles",
    "fig06_speedup_stacks",
    "fig07_pairwise",
    "fig08_consistency",
    "fig09_10_load_imbalance",
    "fig11_nnz_balanced",
    "table1_rcm_vs_metis",
    "bell_formats",
    "moe_dispatch",
    "roofline",
    "spmm_batch",
]


def smoke() -> int:
    """Tiny end-to-end pass for CI: smoke matrices x {baseline, rcm} with
    the autotuned engine through the pipeline facade (plan store included).
    Returns failure count."""
    import numpy as np

    from repro.api import SpmvProblem, plan
    from repro.core.measure import ios
    from repro.matrices import suite

    import jax.numpy as jnp

    failures = 0
    print("name,us_per_call,derived")
    for mname in suite.smoke_names():
        for scheme in ("baseline", "rcm"):
            t0 = time.time()
            try:
                mat = suite.get(mname)
                # interpret-mode keeps the Pallas kernel path covered on CPU
                # whenever the tuner picks a kernel engine
                pl = plan(SpmvProblem(mat,
                                      hints={"use_kernel": "interpret"}),
                          reorder=scheme, engine="auto")
                op = pl.build()
                x0 = jnp.asarray(
                    np.random.default_rng(0).standard_normal(mat.n),
                    jnp.float32)
                ms = float(np.median(ios.run_ios(op.unwrap(), x0, iters=3,
                                                 warmup=1)))
                # correctness gate in the ORIGINAL index space: this also
                # exercises the operator's carried permutation
                want = mat.spmv(np.asarray(x0))
                err = float(np.abs(np.asarray(op(x0)) - want).max())
                scale = float(np.abs(want).max()) + 1e-9
                assert err / scale < 1e-4, (mname, scheme, err / scale)
                info = op.build_info
                derived = {"engine": info["engine"], "ms": round(ms, 3),
                           "cache_hit": info["cache_hit"]}
                us = (time.time() - t0) * 1e6
                print(f"{mname}_{scheme},{us:.0f},"
                      f"\"{json.dumps(derived)}\"", flush=True)
            except Exception as e:
                failures += 1
                us = (time.time() - t0) * 1e6
                print(f"{mname}_{scheme},{us:.0f},"
                      f"\"ERROR: {type(e).__name__}: {e}\"", flush=True)
                traceback.print_exc()
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    if args.smoke:
        raise SystemExit(1 if smoke() else 0)
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            derived = mod.run(quick=args.quick)
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},\"{json.dumps(derived, default=str)}\"",
                  flush=True)
        except Exception as e:
            failures += 1
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},\"ERROR: {type(e).__name__}: {e}\"",
                  flush=True)
            traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
