"""Benchmark orchestrator — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only figXX,...]

Prints one `name,us_per_call,derived` CSV line per benchmark (us_per_call =
module wall time; `derived` = the module's headline findings)."""
from __future__ import annotations

import argparse
import importlib
import json
import time
import traceback

MODULES = [
    "fig01_banded_shuffle",
    "fig03_ios_yax",
    "fig04_scheduling",
    "fig05_profiles",
    "fig06_speedup_stacks",
    "fig07_pairwise",
    "fig08_consistency",
    "fig09_10_load_imbalance",
    "fig11_nnz_balanced",
    "table1_rcm_vs_metis",
    "bell_formats",
    "moe_dispatch",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            derived = mod.run(quick=args.quick)
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},\"{json.dumps(derived, default=str)}\"",
                  flush=True)
        except Exception as e:
            failures += 1
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},\"ERROR: {type(e).__name__}: {e}\"",
                  flush=True)
            traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
