"""Paper Fig. 3: CDF of measured-GFLOPs ratio (X / Real-CG) for X in
{YAX, IOS}. Claim: YAX systematically overpredicts the CG-embedded SpMV
performance; IOS tracks it."""
from __future__ import annotations

import numpy as np

from repro.core.measure import profiles
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, grid, write_csv


def run(quick: bool = False):
    mats = suite.locality_names()
    records = common.run_campaign(matrices=mats, schemes=common.SCHEMES,
                                  profiles=(common.PRIMARY,), tag="locality")
    schemes = common.SCHEMES
    ios_g = grid(records, common.PRIMARY, mats, schemes, "seq_ios_gflops")
    yax_g = grid(records, common.PRIMARY, mats, schemes, "seq_yax_gflops")
    cg_g = grid(records, common.PRIMARY, mats, schemes, "cg_gflops")
    mask = np.isfinite(ios_g) & np.isfinite(cg_g) & np.isfinite(yax_g)
    r_ios = (ios_g / cg_g)[mask].ravel()
    r_yax = (yax_g / cg_g)[mask].ravel()
    rows = []
    for name, r in [("IOS", r_ios), ("YAX", r_yax)]:
        v, c = profiles.cdf(r)
        for vi, ci in zip(v, c):
            rows.append([name, round(float(vi), 4), round(float(ci), 4)])
    write_csv(f"{RESULTS_DIR}/fig03_ios_yax_cdf.csv",
              ["method", "ratio_to_cg", "cdf"], rows)
    return {
        "yax_median_ratio": float(np.median(r_yax)),
        "ios_median_ratio": float(np.median(r_ios)),
        "yax_overpredicts": float(np.mean(r_yax > 1.05)),
        "ios_overpredicts": float(np.mean(r_ios > 1.05)),
    }
