"""Paper Fig. 3: CDF of measured-GFLOPs ratio (X / Real-CG) for X in
{YAX, IOS}. Claim: YAX systematically overpredicts the CG-embedded SpMV
performance; IOS tracks it. A pure view over the locality campaign."""
from __future__ import annotations

import numpy as np

from repro.core.measure import profiles
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv


def run(quick: bool = False):
    mats = suite.locality_names()
    rep = common.campaign_report(common.locality_spec())
    schemes = common.SCHEMES
    ios_g = rep.grid("seq_ios_gflops", mats, schemes)
    yax_g = rep.grid("seq_yax_gflops", mats, schemes)
    cg_g = rep.grid("cg_gflops", mats, schemes)
    r_ios = (ios_g / cg_g).ravel()
    r_yax = (yax_g / cg_g).ravel()
    rows = []
    for name, r in [("IOS", r_ios), ("YAX", r_yax)]:
        v, c = profiles.cdf(r)
        for vi, ci in zip(v, c):
            rows.append([name, round(float(vi), 4), round(float(ci), 4)])
    write_csv(f"{RESULTS_DIR}/fig03_ios_yax_cdf.csv",
              ["method", "ratio_to_cg", "cdf"], rows)
    return {
        "yax_median_ratio": float(np.median(r_yax)),
        "ios_median_ratio": float(np.median(r_ios)),
        "yax_overpredicts": float(np.mean(r_yax > 1.05)),
        "ios_overpredicts": float(np.mean(r_ios > 1.05)),
    }
