"""Paper Fig. 11: nnz-balanced vs static scheduling speedups (reverse CDF)
per scheme. Claim: balance-improving schemes (METIS/PaToH/Louvain) lose
their edge under an nnz-balanced schedule; RCM's curves coincide.
Since PR 5 a "parallel" campaign over the topology-aware facade: the two
schedules are the static / nnz_balanced PARTITIONERS of an 8-device
1d_rows topology, each cell timing the plan's own panels with the
calibrated modelled-parallel protocol (same store as figs 4/9/10)."""
from __future__ import annotations

import numpy as np

from repro.core.measure import profiles
from repro.experiments import ExperimentSpec, MeasurePolicy
from repro.experiments.cells import parallel_variant
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv

P = 8
SCHEDULES = ("static", "nnz_balanced")


def spec(iters: int = 12) -> ExperimentSpec:
    return ExperimentSpec(
        name="fig11_nnz_balanced", matrices=tuple(suite.locality_names()),
        schemes=tuple(common.SCHEMES), engines=("csr",), ps=(P,),
        variants=tuple(parallel_variant("1d_rows", s) for s in SCHEDULES),
        kind="parallel",
        policy=MeasurePolicy(iters=iters, with_yax=False,
                             with_parallel=False, with_metrics=False))


def run(quick: bool = False):
    sp = spec(iters=8 if quick else 12)
    mats = sp.matrices
    rep = common.campaign_report(sp)
    schemes = [s for s in common.SCHEMES if s != "baseline"]
    sp_by_sched = {
        sched: rep.speedup("gflops", mats, schemes,
                           variant=parallel_variant("1d_rows", sched))
        for sched in SCHEDULES}
    rows, out = [], {}
    for i, s in enumerate(schemes):
        for kind in SCHEDULES:
            v, c = profiles.reverse_cdf(sp_by_sched[kind][i])
            for vi, ci in zip(v, c):
                rows.append([s, kind, round(float(vi), 4),
                             round(float(ci), 4)])
        gap = float(np.median(sp_by_sched["static"][i])
                    - np.median(sp_by_sched["nnz_balanced"][i]))
        out[f"{s}_static_minus_balanced_median"] = round(gap, 4)
    write_csv(f"{RESULTS_DIR}/fig11_nnz_balanced.csv",
              ["scheme", "schedule", "speedup", "rev_cdf"], rows)
    return out
