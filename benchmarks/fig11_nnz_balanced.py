"""Paper Fig. 11: nnz-balanced vs static scheduling speedups (reverse CDF)
per scheme. Claim: balance-improving schemes (METIS/PaToH/Louvain) lose
their edge under an nnz-balanced schedule; RCM's curves coincide.
A pure view over the locality campaign."""
from __future__ import annotations

import numpy as np

from repro.core.measure import profiles
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv


def run(quick: bool = False):
    mats = suite.locality_names()
    rep = common.campaign_report(common.locality_spec())
    schemes = [s for s in common.SCHEMES if s != "baseline"]
    sp_static = rep.speedup("par_static_gflops", mats, schemes)
    sp_bal = rep.speedup("par_nnz_balanced_gflops", mats, schemes)
    rows, out = [], {}
    for i, s in enumerate(schemes):
        for kind, sp in [("static", sp_static[i]),
                         ("nnz_balanced", sp_bal[i])]:
            v, c = profiles.reverse_cdf(sp)
            for vi, ci in zip(v, c):
                rows.append([s, kind, round(float(vi), 4),
                             round(float(ci), 4)])
        gap = float(np.median(sp_static[i]) - np.median(sp_bal[i]))
        out[f"{s}_static_minus_balanced_median"] = round(gap, 4)
    write_csv(f"{RESULTS_DIR}/fig11_nnz_balanced.csv",
              ["scheme", "schedule", "speedup", "rev_cdf"], rows)
    return out
