"""Paper Fig. 11: nnz-balanced vs static scheduling speedups (reverse CDF)
per scheme. Claim: balance-improving schemes (METIS/PaToH/Louvain) lose
their edge under an nnz-balanced schedule; RCM's curves coincide."""
from __future__ import annotations

import numpy as np

from repro.core.measure import profiles
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, grid, write_csv


def run(quick: bool = False):
    mats = suite.locality_names()
    records = common.run_campaign(matrices=mats, schemes=common.SCHEMES,
                                  profiles=(common.PRIMARY,), tag="locality")
    schemes = [s for s in common.SCHEMES if s != "baseline"]
    perf_s = grid(records, common.PRIMARY, mats, common.SCHEMES,
                  "par_static_gflops")
    perf_b = grid(records, common.PRIMARY, mats, common.SCHEMES,
                  "par_nnz_balanced_gflops")
    base_s = perf_s[common.SCHEMES.index("baseline")]
    base_b = perf_b[common.SCHEMES.index("baseline")]
    rows, out = [], {}
    for s in schemes:
        i = common.SCHEMES.index(s)
        sp_static = perf_s[i] / base_s
        sp_bal = perf_b[i] / base_b
        for kind, sp in [("static", sp_static), ("nnz_balanced", sp_bal)]:
            v, c = profiles.reverse_cdf(sp[np.isfinite(sp)])
            for vi, ci in zip(v, c):
                rows.append([s, kind, round(float(vi), 4), round(float(ci), 4)])
        gap = float(np.nanmedian(sp_static) - np.nanmedian(sp_bal))
        out[f"{s}_static_minus_balanced_median"] = round(gap, 4)
    write_csv(f"{RESULTS_DIR}/fig11_nnz_balanced.csv",
              ["scheme", "schedule", "speedup", "rev_cdf"], rows)
    return out
