"""Paper Figs. 9 & 10: nnz load imbalance of the static schedule under each
reordering, absolute (Fig. 9, 64 panels) and relative to baseline (Fig. 10).
These are exact analytic quantities (no timing)."""
from __future__ import annotations

import numpy as np

from repro.core.reorder import api as reorder_api
from repro.core.sparse import metrics, partition
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv

P64 = 64


def run(quick: bool = False):
    # locality tier + a representative bench-tier slice (full 33-matrix
    # sweep is reorder-bound; LI is analytic so the subset is unbiased)
    mats = (suite.bench_names()[:8] if quick
            else suite.bench_names()[:12] + suite.locality_names())
    schemes = common.SCHEMES
    rows = []
    li_all = {s: [] for s in schemes}
    for name in mats:
        mat = suite.get(name)
        for scheme in schemes:
            perm = reorder_api.reorder(mat, scheme)
            rmat = mat.permute(perm) if scheme != "baseline" else mat
            li = metrics.load_imbalance(
                rmat, partition.static_partition(rmat, P64))
            rows.append([name, scheme, round(li, 4)])
            li_all[scheme].append(li)
    write_csv(f"{RESULTS_DIR}/fig09_load_imbalance.csv",
              ["matrix", "scheme", "li_static_64"], rows)

    base = np.array(li_all["baseline"])
    out = {}
    rel_rows = []
    for s in schemes:
        if s == "baseline":
            continue
        rel = np.array(li_all[s]) / base     # <1 = improved balance
        out[f"{s}_improved_frac"] = round(float((rel < 0.999).mean()), 3)
        out[f"{s}_geomean_rel_li"] = round(
            float(np.exp(np.mean(np.log(rel)))), 3)
        for name, r in zip(mats, rel):
            rel_rows.append([name, s, round(float(r), 4)])
    write_csv(f"{RESULTS_DIR}/fig10_relative_li.csv",
              ["matrix", "scheme", "li_over_baseline"], rel_rows)
    return out
