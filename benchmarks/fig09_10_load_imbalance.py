"""Paper Figs. 9 & 10: nnz load imbalance of the static schedule under each
reordering, absolute (Fig. 9, 64 panels) and relative to baseline (Fig. 10).
These are exact analytic quantities (no timing) — since PR 5 a "parallel"
campaign over the topology-aware facade: each cell plans a 64-device
1d_rows topology with the static partitioner and records the partition-
quality metrics (LI, cut volume, halo width) alongside the modelled
collective bytes, all in the shared result store (time_spmv=False cells
never build an operator)."""
from __future__ import annotations

import numpy as np

from repro.experiments import ExperimentSpec, MeasurePolicy
from repro.experiments.cells import parallel_variant
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv

P64 = 64
VARIANT = parallel_variant("1d_rows", "static")


def spec(quick: bool = False) -> ExperimentSpec:
    # locality tier + a representative bench-tier slice (full 33-matrix
    # sweep is reorder-bound; LI is analytic so the subset is unbiased)
    mats = (suite.bench_names()[:8] if quick
            else suite.bench_names()[:12] + suite.locality_names())
    return ExperimentSpec(
        name="fig9_li", matrices=tuple(mats), schemes=tuple(common.SCHEMES),
        engines=("csr",), ps=(P64,), variants=(VARIANT,), kind="parallel",
        policy=MeasurePolicy(time_spmv=False, with_yax=False,
                             with_parallel=False, with_metrics=False))


def run(quick: bool = False):
    sp = spec(quick)
    rep = common.campaign_report(sp)
    mats, schemes = sp.matrices, common.SCHEMES
    li = rep.grid("li", mats, schemes)                 # [scheme, matrix]
    rows = [[name, s, round(float(li[i, j]), 4)]
            for j, name in enumerate(mats) for i, s in enumerate(schemes)]
    write_csv(f"{RESULTS_DIR}/fig09_load_imbalance.csv",
              ["matrix", "scheme", "li_static_64"], rows)

    base = li[schemes.index("baseline")]
    out = {}
    rel_rows = []
    for s in schemes:
        if s == "baseline":
            continue
        rel = li[schemes.index(s)] / base     # <1 = improved balance
        out[f"{s}_improved_frac"] = round(float((rel < 0.999).mean()), 3)
        out[f"{s}_geomean_rel_li"] = round(
            float(np.exp(np.mean(np.log(rel)))), 3)
        for name, r in zip(mats, rel):
            rel_rows.append([name, s, round(float(r), 4)])
    write_csv(f"{RESULTS_DIR}/fig10_relative_li.csv",
              ["matrix", "scheme", "li_over_baseline"], rel_rows)
    return out
