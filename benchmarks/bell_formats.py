"""TPU adaptation benchmark (DESIGN.md §3): how reordering changes the
Block-ELL/BCSR format quality — block fill ratio, padded-FLOP overhead, and
distinct x-tiles per row panel. These are the quantities that become MXU
utilization and HBM traffic in the Pallas kernels (structural, no timing)."""
from __future__ import annotations

import numpy as np

from repro.core.reorder import api as reorder_api
from repro.core.sparse import bell, metrics, partition
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv

BM, BN = 8, 128


def run(quick: bool = False):
    mats = suite.bench_names()[:6] if quick else suite.bench_names()[:16]
    rows, out = [], {}
    agg = {s: [] for s in common.SCHEMES}
    for name in mats:
        mat = suite.get(name)
        for scheme in common.SCHEMES:
            perm = reorder_api.reorder(mat, scheme)
            rmat = mat.permute(perm) if scheme != "baseline" else mat
            fill = metrics.block_fill_ratio(rmat, BM, BN)
            nblocks = metrics.num_nonempty_blocks(rmat, BM, BN)
            # padded-FLOP overhead of the BCSR kernel vs nnz flops
            overhead = nblocks * BM * BN / max(rmat.nnz, 1)
            panels = partition.static_partition(rmat, 8)
            xtiles = metrics.distinct_col_blocks(rmat, panels, BN).mean()
            rows.append([name, scheme, round(fill, 5), nblocks,
                         round(overhead, 2), round(float(xtiles), 1)])
            agg[scheme].append(overhead)
    for s, v in agg.items():
        out[f"{s}_geomean_flop_overhead"] = round(
            float(np.exp(np.mean(np.log(np.maximum(v, 1e-9))))), 2)
    write_csv(f"{RESULTS_DIR}/bell_formats.csv",
              ["matrix", "scheme", "fill_ratio", "nblocks",
               "flop_overhead", "mean_xtiles_per_panel"], rows)
    return out
