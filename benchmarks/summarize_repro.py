"""Summarize the paper-claim verdicts from the measured campaigns
(feeds EXPERIMENTS.md §Repro). Run after `python -m benchmarks.run`."""
from __future__ import annotations

import json
import os

import numpy as np

from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, grid


def run(quick=False):
    out = {}
    path = os.path.join(RESULTS_DIR, "campaign_locality.json")
    with open(path) as f:
        rec = json.load(f)
    mats = sorted({r["matrix"] for r in rec.values()})
    S = common.SCHEMES
    perf = grid(rec, common.PRIMARY, mats, S, "seq_ios_gflops")
    yax = grid(rec, common.PRIMARY, mats, S, "seq_yax_gflops")
    cg = grid(rec, common.PRIMARY, mats, S, "cg_gflops")
    par = grid(rec, common.PRIMARY, mats, S, "par_static_gflops")
    ok = np.isfinite(perf).all(axis=0)
    base = perf[S.index("baseline")]

    # claim 5: sequential slowdown fraction per scheme
    for s in S:
        if s == "baseline":
            continue
        sp = perf[S.index(s)][ok] / base[ok]
        out[f"seq_slowdown_frac_{s}"] = round(float((sp < 1.0).mean()), 3)
        out[f"seq_median_speedup_{s}"] = round(float(np.median(sp)), 3)

    # claim 4: pairwise rcm vs others (sequential)
    r = S.index("rcm")
    for s in S:
        if s in ("rcm",):
            continue
        w = float((perf[r][ok] > perf[S.index(s)][ok]).mean())
        out[f"seq_rcm_beats_{s}"] = round(w, 3)

    # claim 2: methodology ratios
    m_ok = np.isfinite(yax).all(0) & np.isfinite(cg).all(0) & ok
    out["yax_over_cg_median"] = round(float(np.median((yax / cg)[:, m_ok])), 3)
    out["ios_over_cg_median"] = round(float(np.median((perf / cg)[:, m_ok])), 3)

    # claim 9 / table 1
    for nm, g in [("IOS", perf), ("CG", cg), ("YAX", yax)]:
        gok = np.isfinite(g).all(0)
        w = int((g[r][gok] > g[S.index("metis")][gok]).sum())
        l = int((g[r][gok] < g[S.index("metis")][gok]).sum())
        out[f"t1_{nm}"] = f"rcm {w}w/{l}l"

    # parallel (modelled): rcm vs metis magnitude story
    p_ok = np.isfinite(par).all(axis=0)
    pbase = par[S.index("baseline")]
    for s in ("rcm", "metis"):
        sp = par[S.index(s)][p_ok] / pbase[p_ok]
        out[f"par_wins_{s}"] = round(float((sp > 1.0).mean()), 3)
        out[f"par_maxspeedup_{s}"] = round(float(sp.max()), 3)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
