"""Summarize the paper-claim verdicts from the measured campaigns
(feeds EXPERIMENTS.md §Repro). Run after `python -m benchmarks.run` —
a pure view over the locality campaign's cells in the result store."""
from __future__ import annotations

import json

import numpy as np

from repro.matrices import suite

from . import common


def run(quick=False):
    out = {}
    mats = suite.locality_names()
    # summarize is a VIEW: fail fast if the campaign was never measured
    # instead of silently launching hours of measurement with no output
    spec = common.locality_spec()
    store = common.result_store()
    missing = [c for c in spec.cells() if store.get(c.key()) is None]
    if missing:
        raise RuntimeError(
            f"locality campaign incomplete: {len(missing)} of "
            f"{len(spec.cells())} cells missing from {store.root} — run "
            f"`python -m benchmarks.run` first (e.g. {missing[0].label()})")
    rep = common.campaign_report(spec, verbose=False)
    S = common.SCHEMES
    perf = rep.grid("seq_ios_gflops", mats, S)
    yax = rep.grid("seq_yax_gflops", mats, S)
    cg = rep.grid("cg_gflops", mats, S)
    par = rep.grid("par_static_gflops", mats, S)
    base = perf[S.index("baseline")]

    # claim 5: sequential slowdown fraction per scheme
    for s in S:
        if s == "baseline":
            continue
        sp = perf[S.index(s)] / base
        out[f"seq_slowdown_frac_{s}"] = round(float((sp < 1.0).mean()), 3)
        out[f"seq_median_speedup_{s}"] = round(float(np.median(sp)), 3)

    # claim 4: pairwise rcm vs others (sequential)
    r = S.index("rcm")
    for s in S:
        if s in ("rcm",):
            continue
        w = float((perf[r] > perf[S.index(s)]).mean())
        out[f"seq_rcm_beats_{s}"] = round(w, 3)

    # claim 2: methodology ratios
    out["yax_over_cg_median"] = round(float(np.median(yax / cg)), 3)
    out["ios_over_cg_median"] = round(float(np.median(perf / cg)), 3)

    # claim 9 / table 1
    for nm, g in [("IOS", perf), ("CG", cg), ("YAX", yax)]:
        w = int((g[r] > g[S.index("metis")]).sum())
        l = int((g[r] < g[S.index("metis")]).sum())
        out[f"t1_{nm}"] = f"rcm {w}w/{l}l"

    # parallel (modelled): rcm vs metis magnitude story
    pbase = par[S.index("baseline")]
    for s in ("rcm", "metis"):
        sp = par[S.index(s)] / pbase
        out[f"par_wins_{s}"] = round(float((sp > 1.0).mean()), 3)
        out[f"par_maxspeedup_{s}"] = round(float(sp.max()), 3)

    # plan-time vs run-time amortization (paper §3 accounting): medians
    # over the campaign's cells at the spec's amortize_iters
    split = rep.plan_run_split()
    if split:
        vals = list(split.values())
        out["median_plan_over_run"] = round(float(np.median(
            [v["plan_over_run"] for v in vals])), 3)
        out["median_amortized_ms"] = round(float(np.median(
            [v["amortized_ms"] for v in vals])), 3)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
