"""Shared measurement campaign for the paper-reproduction benchmarks.

One campaign = the full measurement grid over (machine profile, matrix,
scheme): sequential IOS/YAX, instrumented-CG, and modelled-parallel
static/nnz-balanced timings + structural metrics. Figures (fig*.py) are
pure views over the campaign JSON, so the grid is measured once and cached
under benchmarks/results/.

Machine profiles (DESIGN.md §7 — configs standing in for the paper's four
hosts; consistency claims are about *existence* of inconsistency):
    M1 csr-f32-p8   — primary
    M2 csr-f64-p8   — 2x bandwidth pressure (bigger values+x)
    M3 csr-f32-p4   — fewer cores
    M4 csr-f32-p16  — more cores
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, Iterable

import jax.numpy as jnp
import numpy as np

from repro.api import SpmvProblem, plan
from repro.core.measure import cg, ios, parallel_model
from repro.core.reorder import api as reorder_api
from repro.core.sparse import metrics, partition
from repro.matrices import suite

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

MACHINE_PROFILES = {
    "M1_csr_f32_p8": dict(engine="csr", dtype="float32", p=8),
    "M2_csr_f64_p8": dict(engine="csr", dtype="float64", p=8),
    "M3_csr_f32_p4": dict(engine="csr", dtype="float32", p=4),
    "M4_csr_f32_p16": dict(engine="csr", dtype="float32", p=16),
    # autotuned engine (OSKI-style selection, core/spmv/tune.py)
    "M5_auto_f32_p8": dict(engine="auto", dtype="float32", p=8),
}
PRIMARY = "M1_csr_f32_p8"
# paper schemes + the random-permutation control (Fig. 1's shuffle)
SCHEMES = ["baseline"] + reorder_api.PAPER_SCHEMES + ["random"]

QUICK_MATRICES = [
    "banded_m16384_bw8", "banded_shuf_m16384_bw8", "stencil2d_shuf_128",
    "rmat_s14_e8", "sbm_m16384_k16", "smallworld_m16384_k6",
    "uniform_m16384_d8", "kron_b11_p4",
]
# fig8 consistency subset (all four profiles measured on these)
CONSISTENCY_MATRICES = QUICK_MATRICES + [
    "banded_shuf_m32768_bw63", "stencil3d_shuf_24", "sbm_m32768_k32",
    "rmat_s15_e8", "uniform_m32768_d12", "stencil2d_181",
]


def _key(profile: str, matrix: str, scheme: str) -> str:
    return f"{profile}|{matrix}|{scheme}"


def _cache_path(tag: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"campaign_{tag}.json")


def measure_cell(mat, scheme: str, profile: dict, iters: int = 12,
                 with_cg: bool = True) -> dict:
    """All measurements for one (matrix, scheme, machine profile) cell."""
    dtype = jnp.float32 if profile["dtype"] == "float32" else jnp.float64
    # one plan() + build() through the pipeline facade: repeat campaigns
    # reload plan + device arrays from the plan store (plan time -> ~0)
    pl = plan(SpmvProblem(mat, dtype=profile["dtype"]), reorder=scheme,
              engine=profile["engine"])
    op_full = pl.build()
    rmat_ = pl.reordered_matrix()
    nnz = rmat_.nnz
    build_info = op_full.build_info
    op = op_full.unwrap()      # measurements run in the reordered space
    rng = np.random.default_rng(0)
    x0 = jnp.asarray(rng.standard_normal(rmat_.n), dtype)

    seq_ios = float(np.median(ios.run_ios(op, x0, iters=iters)))
    seq_yax = float(np.median(ios.run_yax(op, x0, iters=iters)))
    rec = {
        "nnz": nnz,
        "seq_ios_ms": seq_ios,
        "seq_yax_ms": seq_yax,
        "seq_ios_gflops": float(ios.gflops(nnz, np.array([seq_ios]))[0]),
        "seq_yax_gflops": float(ios.gflops(nnz, np.array([seq_yax]))[0]),
        # plan-time accounting (paper methodology: preprocessing is
        # reported separately from SpMV run-time, never folded in)
        "engine": build_info["engine"],
        "tuner_choice": pl.tune.engine,
        "tune_ms": pl.tune_ms,
        "format_build_ms": build_info["build_ms"],
        "op_cache_hit": build_info["cache_hit"],
        "op_load_ms": build_info["load_ms"],
    }
    if pl.engine_request == "auto":
        rec["tuner_label"] = pl.tune.label()
        rec["tuner_cost_bytes"] = pl.tune.cost_bytes
    if with_cg:
        cg_ms = float(np.median(cg.cg_measured(op, x0, iters=iters)))
        rec["cg_ms"] = cg_ms
        rec["cg_gflops"] = float(ios.gflops(nnz, np.array([cg_ms]))[0])
    p = profile["p"]
    # panels use the CONCRETE engine the tuner chose for the whole matrix
    # (never "auto": re-tuning per panel would time the tuner, not SpMV)
    panel_engine = build_info["engine"] if profile["engine"] == "auto" \
        else profile["engine"]
    for sched in ("static", "nnz_balanced"):
        ms = parallel_model.modelled_parallel_ms(
            rmat_, p, panel_engine, schedule=sched, iters=max(6, iters // 2))
        rec[f"par_{sched}_ms"] = ms
        rec[f"par_{sched}_gflops"] = float(ios.gflops(nnz, np.array([ms]))[0])
    # structural metrics (analytic, exact)
    panels_s = partition.static_partition(rmat_, p)
    panels_b = partition.nnz_balanced_partition(rmat_, p)
    rec["li_static"] = metrics.load_imbalance(rmat_, panels_s)
    rec["li_nnz_balanced"] = metrics.load_imbalance(rmat_, panels_b)
    rec["bandwidth"] = metrics.bandwidth(rmat_)
    rec["avg_row_bandwidth"] = metrics.avg_row_bandwidth(rmat_)
    rec["cut_volume"] = metrics.cut_volume(rmat_, panels_s)
    rec["block_fill_8x128"] = metrics.block_fill_ratio(rmat_, 8, 128)
    return rec


def run_campaign(matrices: Iterable[str] | None = None,
                 schemes: Iterable[str] = tuple(SCHEMES),
                 profiles: Iterable[str] = (PRIMARY,),
                 iters: int = 12, tag: str = "default",
                 verbose: bool = True) -> Dict[str, dict]:
    """Measure (and cache) the grid. Returns records dict."""
    matrices = list(matrices if matrices is not None else suite.bench_names())
    path = _cache_path(tag)
    records: Dict[str, dict] = {}
    if os.path.exists(path):
        with open(path) as f:
            records = json.load(f)
    dirty = False
    for prof_name in profiles:
        prof = MACHINE_PROFILES[prof_name]
        for mname in matrices:
            mat = None
            for scheme in schemes:
                k = _key(prof_name, mname, scheme)
                if k in records:
                    continue
                if mat is None:
                    mat = suite.get(mname)
                t0 = time.time()
                rec = measure_cell(mat, scheme, prof, iters=iters,
                                   with_cg=(prof_name == PRIMARY))
                rec["profile"] = prof_name
                rec["matrix"] = mname
                rec["scheme"] = scheme
                records[k] = rec
                dirty = True
                if verbose:
                    print(f"[campaign] {k}: ios={rec['seq_ios_gflops']:.2f} "
                          f"gflops ({time.time() - t0:.1f}s)", flush=True)
            if dirty:
                with open(path, "w") as f:
                    json.dump(records, f)
                dirty = False
    return records


def grid(records: Dict[str, dict], profile: str, matrices: list[str],
         schemes: list[str], field: str) -> np.ndarray:
    """[scheme, matrix] array of `field`."""
    out = np.full((len(schemes), len(matrices)), np.nan)
    for i, s in enumerate(schemes):
        for j, m in enumerate(matrices):
            rec = records.get(_key(profile, m, s))
            if rec is not None and field in rec:
                out[i, j] = rec[field]
    return out


def write_csv(path: str, header: list[str], rows: list[list]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(v) for v in r) + "\n")
