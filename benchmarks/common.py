"""Shared campaign specs for the paper-reproduction benchmarks.

The measurement layer is `repro.experiments` (ExperimentSpec → Runner →
ResultStore → Report); this module holds the two standard campaign specs
the figures share plus the store wiring:

  * locality campaign    — locality-tier matrices × all schemes on the
                           primary machine profile, instrumented CG
                           included (figs 3, 5, 6, 7, 11, table 1).
  * consistency campaign — the fig-8 matrix subset × all schemes over
                           EVERY registered machine profile (M1..M5 —
                           DESIGN.md §7; plugin profiles join
                           automatically).

Cells are content-addressed in `benchmarks/results/store/`, so the grid
is measured once no matter how many figures view it, a re-run measures
nothing, and adding a matrix/scheme/profile measures only the delta.

`run_campaign` / `grid` / `measure_cell` remain as deprecation shims for
external callers; figures use the Report accessors (which raise
MissingCellError instead of propagating NaN).
"""
from __future__ import annotations

import os
import warnings
from typing import Dict, Iterable

import numpy as np

from repro.experiments import (PRIMARY, ExperimentSpec, MeasurePolicy,
                               Report, ResultStore, Runner, paper_schemes,
                               write_csv)
from repro.core.registry import PROFILE_REGISTRY

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
STORE_DIR = os.path.join(RESULTS_DIR, "store")

# legacy view: profile name -> dict(engine=, dtype=, p=) over the registry
MACHINE_PROFILES = {name: dict(engine=s.engine, dtype=s.dtype, p=s.p)
                    for name, s in PROFILE_REGISTRY.items()}
# paper schemes + the random-permutation control (Fig. 1's shuffle)
SCHEMES = paper_schemes()

QUICK_MATRICES = [
    "banded_m16384_bw8", "banded_shuf_m16384_bw8", "stencil2d_shuf_128",
    "rmat_s14_e8", "sbm_m16384_k16", "smallworld_m16384_k6",
    "uniform_m16384_d8", "kron_b11_p4",
]
# fig8 consistency subset (all profiles measured on these)
CONSISTENCY_MATRICES = QUICK_MATRICES + [
    "banded_shuf_m32768_bw63", "stencil3d_shuf_24", "sbm_m32768_k32",
    "rmat_s15_e8", "uniform_m32768_d12", "stencil2d_181",
]


def result_store() -> ResultStore:
    """The benchmark result store (REPRO_RESULT_STORE / the operator-cache
    fallback override the default `benchmarks/results/store/`)."""
    return ResultStore(results_dir=RESULTS_DIR)


def campaign_policy(iters: int = 12) -> MeasurePolicy:
    """The standard full-protocol cell policy: IOS + YAX + modelled
    parallel + structural metrics everywhere, instrumented CG on the
    primary profile only (the paper's convention)."""
    return MeasurePolicy(iters=iters, cg_profiles=(PRIMARY,))


def locality_spec(iters: int = 12) -> ExperimentSpec:
    from repro.matrices import suite

    return ExperimentSpec(
        name="locality", matrices=tuple(suite.locality_names()),
        schemes=tuple(SCHEMES), profiles=(PRIMARY,),
        policy=campaign_policy(iters))


def consistency_spec(quick: bool = False, iters: int = 12) -> ExperimentSpec:
    mats = CONSISTENCY_MATRICES[:6] if quick else CONSISTENCY_MATRICES
    return ExperimentSpec(
        name="consistency", matrices=tuple(mats), schemes=tuple(SCHEMES),
        profiles=("*",), policy=campaign_policy(iters))


def campaign_report(spec: ExperimentSpec, verbose: bool = True) -> Report:
    """Measure (resumably) and return the typed report."""
    return Runner(spec, store=result_store(), verbose=verbose).run()


# --------------------------------------------------------------------------
# deprecation shims (no in-repo callers)
# --------------------------------------------------------------------------
def measure_cell(mat, scheme: str, profile: dict, iters: int = 12,
                 with_cg: bool = True) -> dict:
    """Deprecated: use repro.experiments (ExperimentSpec + Runner)."""
    warnings.warn(
        "benchmarks.common.measure_cell() is deprecated; build an "
        "ExperimentSpec and run it through repro.experiments.Runner",
        DeprecationWarning, stacklevel=2)
    from repro.experiments.cells import measure_spmv_cell
    from repro.experiments.spec import Cell

    pol = MeasurePolicy(iters=iters,
                        cg_profiles=("*",) if with_cg else ())
    cell = Cell(kind="spmv", matrix="<adhoc>", scheme=scheme,
                engine=profile["engine"], dtype=profile["dtype"],
                p=int(profile["p"]), k=1, variant="",
                policy=tuple(sorted(pol.resolve("*").items())))
    return measure_spmv_cell(cell, mat)


def run_campaign(matrices: Iterable[str] | None = None,
                 schemes: Iterable[str] = tuple(SCHEMES),
                 profiles: Iterable[str] = (PRIMARY,),
                 iters: int = 12, tag: str = "default",
                 verbose: bool = True) -> Dict[str, dict]:
    """Deprecated: use repro.experiments (ExperimentSpec + Runner).

    Returns the legacy '{profile}|{matrix}|{scheme}'-keyed records dict,
    now backed by the content-addressed result store (the campaign_<tag>
    JSON files are gone; re-runs hit the store instead)."""
    warnings.warn(
        "benchmarks.common.run_campaign() is deprecated; build an "
        "ExperimentSpec and run it through repro.experiments.Runner",
        DeprecationWarning, stacklevel=2)
    from repro.matrices import suite

    mats = tuple(matrices if matrices is not None else suite.bench_names())
    spec = ExperimentSpec(name=tag, matrices=mats, schemes=tuple(schemes),
                          profiles=tuple(profiles),
                          policy=campaign_policy(iters))
    rep = campaign_report(spec, verbose=verbose)
    return {f"{r['profile']}|{r['matrix']}|{r['scheme']}": r
            for r in rep.records}


def grid(records: Dict[str, dict], profile: str, matrices: list[str],
         schemes: list[str], field: str) -> np.ndarray:
    """Deprecated: use Report.grid (strict — raises MissingCellError
    instead of silently yielding NaN)."""
    warnings.warn(
        "benchmarks.common.grid() is deprecated; use "
        "repro.experiments.Report.grid (strict accessors)",
        DeprecationWarning, stacklevel=2)
    out = np.full((len(schemes), len(matrices)), np.nan)
    for i, s in enumerate(schemes):
        for j, m in enumerate(matrices):
            rec = records.get(f"{profile}|{m}|{s}")
            if rec is not None and field in rec:
                out[i, j] = rec[field]
    return out


__all__ = [
    "CONSISTENCY_MATRICES", "MACHINE_PROFILES", "PRIMARY", "QUICK_MATRICES",
    "RESULTS_DIR", "SCHEMES", "STORE_DIR", "campaign_policy",
    "campaign_report", "consistency_spec", "grid", "locality_spec",
    "measure_cell", "result_store", "run_campaign", "write_csv",
]
