"""Paper Fig. 4 (adapted, DESIGN.md §3): scheduling-policy sweep.

OpenMP dynamic/guided have no TPU analogue (static SPMD), so the reproduced
claim is the STATIC family's ordering: default static (one maximal
contiguous chunk) >= static,chunk for chunk in {16, 64} — temporal
locality grows with chunk size. Parallel times come from the calibrated
panel model (modelled parallel, labelled).

A spec over the "schedule" cell kind: the scheduling policy is the
variants axis (static_c<chunk> cells time each thread's strided row set
on its own gathered submatrix — see repro/experiments/cells.py).
"""
from __future__ import annotations

import numpy as np

from repro.core.measure import profiles
from repro.experiments import ExperimentSpec, MeasurePolicy
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv

P = 8
POLICIES = ("static_default", "static_c16", "static_c64", "nnz_balanced")


def spec(quick: bool = False) -> ExperimentSpec:
    mats = suite.locality_names()[:4] if quick else suite.locality_names()
    return ExperimentSpec(
        name="fig4_scheduling", matrices=tuple(mats), schemes=("baseline",),
        engines=("csr",), ps=(P,), variants=POLICIES, kind="schedule",
        policy=MeasurePolicy(iters=4 if quick else 6))


def run(quick: bool = False):
    sp = spec(quick)
    rep = common.campaign_report(sp)
    rows = []
    summary = {p: [] for p in POLICIES}
    for name in sp.matrices:
        for pol in POLICIES:
            rec = rep.cell(name, "baseline", variant=pol)
            rows.append([name, pol, round(rec["modelled_par_ms"], 3),
                         round(rec["gflops"], 4)])
            summary[pol].append(rec["gflops"])
    write_csv(f"{RESULTS_DIR}/fig04_scheduling.csv",
              ["matrix", "policy", "modelled_par_ms", "gflops"], rows)
    geo = {p: profiles.geomean(np.maximum(v, 1e-9))
           for p, v in summary.items()}
    return {"geomean_gflops": geo,
            "default_static_wins": geo["static_default"] >= geo["static_c16"]}
