"""Paper Fig. 4 (adapted, DESIGN.md §3): scheduling-policy sweep.

OpenMP dynamic/guided have no TPU analogue (static SPMD), so the reproduced
claim is the STATIC family's ordering: default static (one maximal
contiguous chunk) >= static,chunk for chunk in {1,16,32,64} — temporal
locality grows with chunk size. Parallel times come from the calibrated
panel model (modelled parallel, labelled)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.measure import ios, parallel_model
from repro.core.sparse import partition
from repro.core.spmv.ops import build_operator
from repro.matrices import suite

from .common import RESULTS_DIR, write_csv

P = 8


def _chunked_static_ms(mat, chunk, iters):
    """Modelled parallel time under static,chunk scheduling: each thread's
    rows are a strided set; its time is measured on its own gathered
    submatrix (includes the locality loss of striding). IOS semantics: the
    panel's output refreshes x at ITS OWN row positions (x stays full-size —
    feeding the short y back as x would silently clamp gather indices)."""
    import time as _time

    panels = partition.chunked_cyclic_panels(mat.m, P, chunk)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(mat.n), jnp.float32)
    rows_dev = None
    worst = 0.0
    for rows in panels:
        sub = _rows_submatrix(mat, rows)
        op = build_operator(sub, "csr", nnz_bucket=4096)
        rows_dev = jnp.asarray(rows)
        xi = x
        times = []
        for i in range(iters + 2):
            t0 = _time.perf_counter()
            y = op(xi)
            y.block_until_ready()
            if i >= 2:
                times.append((_time.perf_counter() - t0) * 1e3)
            xi = xi.at[rows_dev].set(y[: rows.size])
        worst = max(worst, float(np.median(times)))
    return worst + parallel_model.ALPHA_SYNC_MS


def _rows_submatrix(mat, rows):
    from repro.core.sparse.csr import CSRMatrix

    rp = mat.rowptr.astype(np.int64)
    counts = (rp[rows + 1] - rp[rows])
    idx = np.concatenate([np.arange(rp[r], rp[r + 1]) for r in rows]) \
        if rows.size else np.empty(0, np.int64)
    rowptr = np.zeros(rows.size + 1, dtype=np.int64)
    rowptr[1:] = np.cumsum(counts)
    rowptr = rowptr.astype(np.int32)
    return CSRMatrix(rowptr=rowptr, cols=mat.cols[idx], vals=mat.vals[idx],
                     shape=(rows.size, mat.n))


def run(quick: bool = False):
    iters = 4 if quick else 6
    mats = suite.locality_names()[:4] if quick else suite.locality_names()
    policies = ["static_default", "static_c16", "static_c64", "nnz_balanced"]
    rows = []
    summary = {p: [] for p in policies}
    for name in mats:
        mat = suite.get(name)
        res = {}
        res["static_default"] = parallel_model.modelled_parallel_ms(
            mat, P, "csr", schedule="static", iters=iters)
        res["static_c16"] = _chunked_static_ms(mat, 16, iters)
        res["static_c64"] = _chunked_static_ms(mat, 64, iters)
        res["nnz_balanced"] = parallel_model.modelled_parallel_ms(
            mat, P, "csr", schedule="nnz_balanced", iters=iters)
        for pol in policies:
            gf = float(ios.gflops(mat.nnz, np.array([res[pol]]))[0])
            rows.append([name, pol, round(res[pol], 3), round(gf, 4)])
            summary[pol].append(gf)
    write_csv(f"{RESULTS_DIR}/fig04_scheduling.csv",
              ["matrix", "policy", "modelled_par_ms", "gflops"], rows)
    geo = {p: float(np.exp(np.mean(np.log(np.maximum(v, 1e-9)))))
           for p, v in summary.items()}
    return {"geomean_gflops": geo,
            "default_static_wins": geo["static_default"] >= geo["static_c16"]}
