"""Paper Fig. 4 (adapted, DESIGN.md §3): scheduling-policy sweep.

OpenMP dynamic/guided have no TPU analogue (static SPMD), so the reproduced
claim is the STATIC family's ordering: default static (one maximal
contiguous chunk) >= static,chunk for chunk in {16, 64} — temporal
locality grows with chunk size. Parallel times come from the calibrated
panel model (modelled parallel, labelled).

Since PR 5 the policies are PARTITIONERS of an 8-device 1d_rows topology
("parallel" cell kind): static, chunked_cyclic_c16/c64 (whose grouping
permutation makes each thread's strided row set a contiguous panel —
including its striding locality loss), and nnz_balanced. Same store, same
CSV schema as before.
"""
from __future__ import annotations

import numpy as np

from repro.core.measure import profiles
from repro.experiments import ExperimentSpec, MeasurePolicy
from repro.experiments.cells import parallel_variant
from repro.matrices import suite

from . import common
from .common import RESULTS_DIR, write_csv

P = 8
# CSV policy label -> partitioner (the legacy fig-4 naming is the schema)
POLICY_PARTITIONERS = {
    "static_default": "static",
    "static_c16": "chunked_cyclic_c16",
    "static_c64": "chunked_cyclic_c64",
    "nnz_balanced": "nnz_balanced",
}
POLICIES = tuple(POLICY_PARTITIONERS)


def spec(quick: bool = False) -> ExperimentSpec:
    mats = suite.locality_names()[:4] if quick else suite.locality_names()
    return ExperimentSpec(
        name="fig4_scheduling", matrices=tuple(mats), schemes=("baseline",),
        engines=("csr",), ps=(P,), kind="parallel",
        variants=tuple(parallel_variant("1d_rows", p)
                       for p in POLICY_PARTITIONERS.values()),
        policy=MeasurePolicy(iters=4 if quick else 6, with_yax=False,
                             with_parallel=False, with_metrics=False))


def run(quick: bool = False):
    sp = spec(quick)
    rep = common.campaign_report(sp)
    rows = []
    summary = {p: [] for p in POLICIES}
    for name in sp.matrices:
        for pol in POLICIES:
            var = parallel_variant("1d_rows", POLICY_PARTITIONERS[pol])
            rec = rep.cell(name, "baseline", variant=var)
            rows.append([name, pol, round(rec["modelled_par_ms"], 3),
                         round(rec["gflops"], 4)])
            summary[pol].append(rec["gflops"])
    write_csv(f"{RESULTS_DIR}/fig04_scheduling.csv",
              ["matrix", "policy", "modelled_par_ms", "gflops"], rows)
    geo = {p: profiles.geomean(np.maximum(v, 1e-9))
           for p, v in summary.items()}
    return {"geomean_gflops": geo,
            "default_static_wins": geo["static_default"] >= geo["static_c16"]}
