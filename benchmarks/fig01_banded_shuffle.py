"""Paper Fig. 1: banded (1M x 1M, half-bw 15) vs randomly shuffled twin.

The paper reports 108 vs 32 GFLOPs on a 64-core machine; here the same
structural contrast is measured sequentially on the XLA-CPU backend (one
physical core, DESIGN.md §7) — the claim under reproduction is the RATIO.

A timing-only spec (no YAX/CG/parallel/metrics: the 1M-row pair makes the
full protocol needlessly expensive) on the fixed csr engine.
"""
from __future__ import annotations

from repro.experiments import ExperimentSpec, MeasurePolicy

from . import common
from .common import RESULTS_DIR, write_csv

MATRICES = ("fig1_banded", "fig1_shuffled")


def spec(quick: bool = False) -> ExperimentSpec:
    return ExperimentSpec(
        name="fig1_pair", matrices=MATRICES, schemes=("baseline",),
        engines=("csr",),
        policy=MeasurePolicy(iters=6 if quick else 12, with_yax=False,
                             with_parallel=False, with_metrics=False))


def run(quick: bool = False):
    rep = common.campaign_report(spec(quick))
    rows = []
    for name in MATRICES:
        rec = rep.cell(name, "baseline")
        rows.append([name, rec["m"], rec["nnz"],
                     round(rec["seq_ios_ms"], 3),
                     round(rec["seq_ios_gflops"], 4)])
    ratio = rows[0][4] / rows[1][4]
    rows.append(["ratio_banded_over_shuffled", "", "", "", round(ratio, 3)])
    write_csv(f"{RESULTS_DIR}/fig01_banded_shuffle.csv",
              ["matrix", "m", "nnz", "ios_ms", "gflops"], rows)
    return {"banded_gflops": rows[0][4], "shuffled_gflops": rows[1][4],
            "ratio": ratio}
