"""Paper Fig. 1: banded (1M x 1M, half-bw 15) vs randomly shuffled twin.

The paper reports 108 vs 32 GFLOPs on a 64-core machine; here the same
structural contrast is measured sequentially on the XLA-CPU backend (one
physical core, DESIGN.md §7) — the claim under reproduction is the RATIO.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.measure import ios
from repro.core.spmv.ops import build_operator
from repro.matrices import suite

from .common import RESULTS_DIR, write_csv


def run(quick: bool = False):
    iters = 6 if quick else 12
    rows = []
    for name in ("fig1_banded", "fig1_shuffled"):
        mat = suite.get(name)
        op = build_operator(mat, "csr")
        x = jnp.asarray(np.random.default_rng(0).standard_normal(mat.n),
                        jnp.float32)
        ms = float(np.median(ios.run_ios(op, x, iters=iters)))
        gf = float(ios.gflops(mat.nnz, np.array([ms]))[0])
        rows.append([name, mat.m, mat.nnz, round(ms, 3), round(gf, 4)])
    ratio = rows[0][4] / rows[1][4]
    rows.append(["ratio_banded_over_shuffled", "", "", "", round(ratio, 3)])
    write_csv(f"{RESULTS_DIR}/fig01_banded_shuffle.csv",
              ["matrix", "m", "nnz", "ios_ms", "gflops"], rows)
    return {"banded_gflops": rows[0][4], "shuffled_gflops": rows[1][4],
            "ratio": ratio}
