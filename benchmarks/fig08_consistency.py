"""Paper Fig. 8: cross-machine consistency of reordering speedups.

Machines -> measurement profiles M1..M4 (DESIGN.md §7: engine dtype and
core-count variations on this host; documented deviation — the reproduced
claim is the EXISTENCE of inconsistency, Consistent% < 100 at low tau).
"""
from __future__ import annotations

import numpy as np

from repro.core.measure import profiles
from . import common
from .common import RESULTS_DIR, grid, write_csv

TAUS = [1.1, 1.25, 1.5, 2.0]


def run(quick: bool = False):
    mats = common.CONSISTENCY_MATRICES[:6] if quick else common.CONSISTENCY_MATRICES
    profs = list(common.MACHINE_PROFILES)
    records = common.run_campaign(matrices=mats, schemes=common.SCHEMES,
                                  profiles=profs, tag="consistency")
    schemes = [s for s in common.SCHEMES if s != "baseline"]
    rows, out = [], {}
    for mode, field in [("sequential", "seq_ios_gflops"),
                        ("parallel_modelled", "par_static_gflops")]:
        for s in schemes:
            sp_by_machine = []
            for prof in profs:
                perf = grid(records, prof, mats, common.SCHEMES, field)
                base = perf[common.SCHEMES.index("baseline")]
                sp_by_machine.append(perf[common.SCHEMES.index(s)] / base)
            sp = np.stack(sp_by_machine)           # [machines, matrices]
            ok = np.isfinite(sp).all(axis=0)
            for tau in TAUS:
                cons, n = profiles.consistency_ratio(sp[:, ok], tau)
                rows.append([mode, s, tau, round(cons, 3), n])
                out[f"{mode}_{s}_tau{tau}"] = round(cons, 3)
    write_csv(f"{RESULTS_DIR}/fig08_consistency.csv",
              ["mode", "scheme", "tau", "consistent_pct", "n_candidates"], rows)
    return out
