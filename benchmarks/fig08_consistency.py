"""Paper Fig. 8: cross-machine consistency of reordering speedups.

Machines -> the registered machine profiles M1..M5 (DESIGN.md §7: engine
dtype and core-count variations on this host; documented deviation — the
reproduced claim is the EXISTENCE of inconsistency, Consistent% < 100 at
low tau). A view over the consistency campaign, which iterates EVERY
registered profile (profiles="*") — a plugin profile joins this figure
by calling register_profile.
"""
from __future__ import annotations

from repro.core.registry import PROFILE_REGISTRY

from . import common
from .common import RESULTS_DIR, write_csv

TAUS = [1.1, 1.25, 1.5, 2.0]


def run(quick: bool = False):
    sp = common.consistency_spec(quick)
    rep = common.campaign_report(sp)
    mats = sp.matrices
    profs = list(PROFILE_REGISTRY)
    schemes = [s for s in common.SCHEMES if s != "baseline"]
    rows, out = [], {}
    for mode, field in [("sequential", "seq_ios_gflops"),
                        ("parallel_modelled", "par_static_gflops")]:
        for s in schemes:
            # one speedup stack per (mode, scheme), swept over all taus
            for tau, (cons, n) in zip(
                    TAUS, rep.consistency(field, mats, s, profs, TAUS)):
                rows.append([mode, s, tau, round(cons, 3), n])
                out[f"{mode}_{s}_tau{tau}"] = round(cons, 3)
    write_csv(f"{RESULTS_DIR}/fig08_consistency.csv",
              ["mode", "scheme", "tau", "consistent_pct", "n_candidates"],
              rows)
    return out
