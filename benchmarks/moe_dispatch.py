"""Paper technique inside the LM framework: MoE routing as a sparse matrix.

A thin VIEW over the `"workload"` campaign cells (benchmarks/workloads
`moe_dispatch_spec`): the seed's (E, k) grid at d=128, measured through
the Problem→Plan→Operator pipeline under the WorkloadSession
amortization policy instead of raw perf_counter loops — sorted dispatch
is the sparse operator chain, onehot the GShard-style scatter oracle
(repro.workloads.adapters). CSV schema unchanged: the router LI metric
(paper §6.1), the drop fraction under the capacity (= nnz-balanced)
schedule, and wall-clock of sorted (reordered) vs one-hot (unreordered)
dispatch."""
from __future__ import annotations

import re

from repro.experiments import Runner

from .common import RESULTS_DIR, result_store, write_csv
from .workloads import moe_dispatch_spec


def run(quick: bool = False):
    tokens = 2048 if quick else 8192
    spec = moe_dispatch_spec(tokens)
    rep = Runner(spec, store=result_store(), verbose=False).run()
    rows, out = [], {}
    for rec in rep.records:
        m = re.search(r"moe-e(\d+)-k(\d+)", rec["matrix"])
        cfg = f"e{m.group(1)}_k{m.group(2)}"
        li = round(float(rec["li_mean"]), 3)
        drop = round(float(rec["drop_frac"]), 4)
        rows.append([cfg, "sorted", round(rec["sorted_ms"], 2), li, drop])
        rows.append([cfg, "onehot", round(rec["onehot_ms"], 2), li, drop])
        out[f"{cfg}_dispatch_agree"] = bool(rec["dispatch_agree"])
        out[f"{cfg}_sorted_ms"] = round(rec["sorted_ms"], 2)
        out[f"{cfg}_onehot_ms"] = round(rec["onehot_ms"], 2)
        out[f"{cfg}_router_li"] = li
    write_csv(f"{RESULTS_DIR}/moe_dispatch.csv",
              ["config", "dispatch", "ms", "router_li", "drop_frac"], rows)
    return out
