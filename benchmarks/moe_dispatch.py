"""Paper technique inside the LM framework: MoE routing as a sparse matrix.

Measures (CPU, reduced config): the router LI metric (paper §6.1), the drop
fraction under the capacity (= nnz-balanced) schedule, and wall-clock of
sorted (reordered) vs one-hot (unreordered) dispatch."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.layers import moe as MOE

from .common import RESULTS_DIR, write_csv


def run(quick: bool = False):
    d, tokens = 128, 2048 if quick else 8192
    rows, out = [], {}
    for e, k in [(16, 2), (64, 8)]:
        cfg_s = MoEConfig(num_experts=e, top_k=k, d_ff_expert=256,
                          dispatch="sorted")
        cfg_o = MoEConfig(num_experts=e, top_k=k, d_ff_expert=256,
                          dispatch="onehot")
        params = MOE.init_moe(jax.random.PRNGKey(0), d, cfg_s)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, tokens, d), jnp.float32)
        results = {}
        for nm, cfg in [("sorted", cfg_s), ("onehot", cfg_o)]:
            f = jax.jit(lambda p, xx, c=cfg: MOE.moe_layer(p, xx, c))
            y, m = f(params, x)
            y.block_until_ready()
            t0 = time.perf_counter()
            for _ in range(5):
                y, m = f(params, x)
                y.block_until_ready()
            dt = (time.perf_counter() - t0) / 5 * 1e3
            results[nm] = (dt, y, m)
            rows.append([f"e{e}_k{k}", nm, round(dt, 2),
                         round(float(m["router_li"]), 3),
                         round(float(m["drop_frac"]), 4)])
        # both dispatches agree numerically
        ys, yo = results["sorted"][1], results["onehot"][1]
        err = float(jnp.abs(ys - yo).max())
        out[f"e{e}_k{k}_dispatch_agree"] = err < 1e-3
        out[f"e{e}_k{k}_sorted_ms"] = round(results["sorted"][0], 2)
        out[f"e{e}_k{k}_onehot_ms"] = round(results["onehot"][0], 2)
        out[f"e{e}_k{k}_router_li"] = round(float(results["sorted"][2]["router_li"]), 3)
    write_csv(f"{RESULTS_DIR}/moe_dispatch.csv",
              ["config", "dispatch", "ms", "router_li", "drop_frac"], rows)
    return out
